"""Schedule <-> runtime agreement: the pipelined dual-core executor must
run exactly the analytical schedule (slot offsets) and reproduce the
sequential forward bit-for-bit (ISSUE-3 satellite)."""
import jax
import numpy as np
import pytest

from repro.core.arch import BoardModel, DUAL_BASELINE
from repro.core.scheduler import best_schedule, build_schedule
from repro.dualcore.program import build_program
from repro.dualcore.runtime import DualCoreRunner, build_exec_plan
from repro.models.cnn import build_model
from repro.models.zoo import get_graph

B = BoardModel()
MODELS = ("mobilenet_v1", "mobilenet_v2", "squeezenet")


def _balanced(graph):
    return build_schedule(graph, DUAL_BASELINE, B, "balanced")


def _images(n, size=48, batch=1):
    return [jax.random.normal(k, (batch, size, size, 3))
            for k in jax.random.split(jax.random.PRNGKey(0), n)]


# --------------------------------------------------------------------------
# exec-plan structure
# --------------------------------------------------------------------------
@pytest.mark.parametrize("model", MODELS)
@pytest.mark.parametrize("scheme", ("layer_type", "balanced"))
def test_exec_plan_covers_and_alternates(model, scheme):
    g = get_graph(model)
    sched = (_balanced(g) if scheme == "balanced"
             else build_schedule(g, DUAL_BASELINE, B, scheme))
    prog = build_program(g, use_pallas=True, fuse=False)
    plan = build_exec_plan(prog, sched)
    es = plan.exec_schedule
    assert es.validate_alternating()
    names = [n for gr in plan.groups for n in gr.layers]
    assert names == [l.name for l in g.topological_order()]
    # the exec twin is a real Schedule: T_b2 and the simulator apply to
    # exactly what the runtime executes
    assert es.t_b2() >= max(es.group_latencies)


def test_exec_plan_accepts_load_balanced_schedules():
    """Alg.1 splits layers into .a/.b halves across cores; the runtime maps
    each base layer to the core holding its dominant split."""
    g = get_graph("mobilenet_v1")
    sched = best_schedule(g, DUAL_BASELINE, B)     # includes +lb candidates
    prog = build_program(g, use_pallas=True, fuse=False)
    plan = build_exec_plan(prog, sched)
    names = [n for gr in plan.groups for n in gr.layers]
    assert sorted(names) == sorted(l.name for l in g.layers)


def test_exec_plan_rejects_foreign_schedule():
    g1, g2 = get_graph("mobilenet_v1"), get_graph("squeezenet")
    sched = _balanced(g2)
    prog = build_program(g1, use_pallas=True, fuse=False)
    with pytest.raises(ValueError, match="does not cover"):
        build_exec_plan(prog, sched)


@pytest.mark.parametrize("model", MODELS)
def test_model_side_pipeline_speedup(model):
    """Acceptance: two-stream pipelined throughput >= 1.2x sequential,
    model-side, for the schedule the runtime actually executes."""
    g = get_graph(model)
    prog = build_program(g, use_pallas=True, fuse=False)
    es = build_exec_plan(prog, _balanced(g)).exec_schedule
    assert 2 * sum(es.group_latencies) / es.t_b2() >= 1.2


# --------------------------------------------------------------------------
# execution order: the Fig.4b slot offsets, for real
# --------------------------------------------------------------------------
def test_pipelined_order_matches_schedule_slot_offsets():
    params, _, g = build_model("mobilenet_v1")
    runner = DualCoreRunner("mobilenet_v1", params, _balanced(g),
                            use_pallas=False, fuse=False)
    n_g = len(runner.groups)
    record = []
    runner.run_pipelined(_images(3, size=32), record=record)
    # stream i executes group k exactly at slot i + k (one-slot offset)
    assert [(s, i, gi) for s, i, gi, _ in record] == \
        [(slot, i, slot - i) for slot in range(n_g + 2)
         for i in range(3) if 0 <= slot - i < n_g]
    # within a slot, neighbouring streams run on different cores (the
    # alternation invariant realised at execution time)
    by_slot: dict = {}
    for slot, _i, _gi, core in record:
        by_slot.setdefault(slot, []).append(core)
    for slot, cores in by_slot.items():
        assert all(a != b for a, b in zip(cores, cores[1:])), (slot, cores)
    assert any(len(set(c)) == 2 for c in by_slot.values())


def test_degenerate_single_group_still_runs():
    # squeezenet under layer_type has no dwconv -> everything on the c-core
    params, fwd, g = build_model("squeezenet")
    sched = build_schedule(g, DUAL_BASELINE, B, "layer_type")
    runner = DualCoreRunner("squeezenet", params, sched, use_pallas=False,
                            fuse=False)
    assert len(runner.groups) == 1
    (x,) = _images(1, size=32)
    out = runner.run_pipelined([x])[0]
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(fwd(params, x)))


# --------------------------------------------------------------------------
# bitwise agreement with the sequential Pallas forward (CPU interpret)
# --------------------------------------------------------------------------
@pytest.mark.parametrize("model", [
    "mobilenet_v1",
    pytest.param("mobilenet_v2", marks=pytest.mark.slow),
    pytest.param("squeezenet", marks=pytest.mark.slow),
])
def test_pipelined_bitwise_equals_sequential_pallas(model):
    """The pipelined runtime partitions the *same* step program the
    sequential ``use_pallas=True`` forward runs, so outputs must be
    bitwise-identical (eager group execution, CPU interpret kernels)."""
    params, fwd, g = build_model(model)
    runner = DualCoreRunner(model, params, _balanced(g), use_pallas=True,
                            fuse=True, jit_groups=False)
    imgs = _images(2)
    outs = runner.run_pipelined(imgs)
    for x, out in zip(imgs, outs):
        ref = fwd(params, x, use_pallas=True)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_multi_stream_pipelining_matches_forward():
    """Four staggered streams (beyond the paper's two) still reproduce the
    per-image forward exactly, jit-compiled groups included."""
    params, fwd, g = build_model("mobilenet_v1")
    runner = DualCoreRunner("mobilenet_v1", params, _balanced(g),
                            use_pallas=False, fuse=False, jit_groups=True)
    imgs = _images(4, size=32)
    outs = runner.run_pipelined(imgs)
    for x, out in zip(imgs, outs):
        np.testing.assert_array_equal(np.asarray(out),
                                      np.asarray(fwd(params, x)))


def test_group_fusion_degrades_to_per_layer_on_xla_path():
    """The fused-block kernels are Pallas-only: with use_pallas=False the
    default fuse='group' must not emit fused pallas_calls, and the output
    must stay bitwise-equal to the XLA forward."""
    params, fwd, g = build_model("mobilenet_v1")
    runner = DualCoreRunner("mobilenet_v1", params, _balanced(g),
                            use_pallas=False, fuse="group")
    assert all(len(s.layers) == 1
               for gr in runner.groups for s in gr.steps)
    (x,) = _images(1, size=32)
    out = runner.run_pipelined([x])[0]
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(fwd(params, x)))


def test_group_fusion_stays_inside_core_groups():
    """fuse='group' re-fuses dw->pw chains only when the schedule kept the
    pair on one core; fused pallas_calls must never straddle a boundary."""
    params, fwd, g = build_model("mobilenet_v1")
    runner = DualCoreRunner("mobilenet_v1", params, _balanced(g),
                            use_pallas=True, fuse="group")
    fused = [s for gr in runner.groups for s in gr.steps
             if len(s.layers) > 1]
    assert fused, "balanced schedule should leave some dw->pw pairs whole"
    for gr in runner.groups:
        for s in gr.steps:
            assert set(s.layers) <= set(gr.layers)
    # still the same function, just a different kernel partitioning
    (x,) = _images(1)
    out = runner.run_pipelined([x])[0]
    ref = fwd(params, x, use_pallas=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-3, atol=2e-3)
