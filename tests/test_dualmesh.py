"""dualmesh: the paper's design flow on TPU submeshes (DESIGN.md §2)."""
import jax
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs.registry import get_arch, get_smoke
from repro.dualmesh import (ALLOCATIONS, DualMeshRunner, TpuModel,
                            best_schedule, build, decode_cost, load_balance,
                            prefill_cost, request_stages, search, split_mesh)
from repro.dualmesh.partition import abstract_split
from repro.dualmesh.schedule import stage_cost

CFG = get_arch("qwen2_5_14b")
HW = TpuModel()


# --------------------------------------------------------------------------
# Cost model properties
# --------------------------------------------------------------------------
def test_prefill_is_compute_bound_decode_is_memory_bound():
    """The paper's motivating heterogeneity, reproduced on the LM side:
    prefill (regular-conv analogue) is compute-bound; decode (depthwise
    analogue) is memory/floor-bound."""
    p = prefill_cost(CFG, batch=8, seq=8192, chips=64, hw=HW, tp=8)
    d = decode_cost(CFG, batch=8, kv_len=8192, chips=64, steps=256,
                    hw=HW, tp=8)
    assert p.bound == "compute"
    assert d.bound in ("memory", "collective")
    # arithmetic-intensity gap: decode latency is dominated by bytes
    assert d.t_memory / max(d.t_compute, 1e-12) > 3


def test_decode_scaling_saturates():
    """Adding chips to decode hits the per-step floor (the PE-efficiency
    analogue) — the reason a dedicated small p-submesh wins."""
    d64 = decode_cost(CFG, 8, 8192, 64, steps=256, hw=HW, tp=8).latency
    d256 = decode_cost(CFG, 8, 8192, 256, steps=256, hw=HW, tp=8).latency
    assert d256 > d64 / 4 * 1.5          # far from linear scaling


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 64), st.sampled_from([512, 4096, 32768]),
       st.integers(8, 256))
def test_costs_positive_monotone(batch, seq, chips):
    p = prefill_cost(CFG, batch, seq, chips, hw=HW)
    assert p.latency > 0
    p2 = prefill_cost(CFG, batch, 2 * seq, chips, hw=HW)
    assert p2.latency > p.latency        # more tokens, more time


# --------------------------------------------------------------------------
# Partitioning
# --------------------------------------------------------------------------
def test_abstract_split_counts():
    d = abstract_split(256, 0.75, tp_c=16, tp_p=4)
    assert d.c_chips + d.p_chips == 256
    assert abs(d.theta - 0.75) < 0.05
    assert d.c_mesh.shape["model"] <= 16


def test_split_mesh_single_device_degenerate():
    d = split_mesh(jax.devices(), 0.5)
    assert d.c_chips >= 1 and d.p_chips >= 1


# --------------------------------------------------------------------------
# Scheduling (paper §V re-targeted)
# --------------------------------------------------------------------------
def _stages():
    return request_stages(CFG, [(8, 4096, 64), (8, 4096, 64)])


def test_schedule_covers_all_stages():
    dual = abstract_split(256, 0.5)
    for scheme in ALLOCATIONS:
        s = build(_stages(), CFG, dual, HW, scheme)
        n = sum(len(g.stages) for g in s.groups)
        assert n == len(_stages())
        assert all(a.mesh != b.mesh
                   for a, b in zip(s.groups, s.groups[1:]))


def test_load_balance_never_worse():
    dual = abstract_split(256, 0.5)
    s = build(_stages(), CFG, dual, HW, "stage_type")
    lb = load_balance(s)
    assert lb.makespan() <= s.makespan() + 1e-12
    # token conservation through splits
    def toks(sched):
        return sum(st.seq if st.kind == "prefill" else 0
                   for g in sched.groups for st in g.stages)
    assert toks(lb) == toks(s)


def test_best_schedule_beats_single_allocation():
    dual = abstract_split(256, 0.5)
    best = best_schedule(_stages(), CFG, dual, HW)
    worst = max(build(_stages(), CFG, dual, HW, sch).makespan()
                for sch in ALLOCATIONS)
    assert best.makespan() <= worst


# --------------------------------------------------------------------------
# Design-flow search (paper §V-B re-targeted)
# --------------------------------------------------------------------------
@pytest.mark.slow
def test_search_finds_dual_win_on_balanced_workload():
    stages = request_stages(CFG, [(8, 8192, 256)] * 4)
    res = search(stages, CFG, n_devices=256, max_evals=10)
    single = sum(stage_cost(s, CFG, 256, 16, HW) for s in stages) * 2
    assert res.makespan < single          # dual-OPU claim, LM domain
    assert 0.05 <= res.theta <= 0.95


@pytest.mark.slow
def test_search_theta_tracks_workload_mix():
    """More decode-heavy workload -> larger share for the decode submesh
    (the Table VI 'heterogeneity drives theta' result, LM domain)."""
    bal = search(request_stages(CFG, [(8, 8192, 64)] * 4), CFG,
                 n_devices=256, max_evals=10)
    dec = search(request_stages(CFG, [(8, 1024, 1024)] * 4), CFG,
                 n_devices=256, max_evals=10)
    # share of chips of the submesh that runs the decode stages
    def decode_share(res):
        sched = res.schedule
        c_dec = sum(1 for g in sched.groups for s in g.stages
                    if s.kind == "decode" and g.mesh == "c")
        p_dec = sum(1 for g in sched.groups for s in g.stages
                    if s.kind == "decode" and g.mesh == "p")
        share_c = res.dual.c_chips / (res.dual.c_chips + res.dual.p_chips)
        return share_c if c_dec >= p_dec else 1 - share_c
    assert decode_share(dec) >= decode_share(bal) - 0.05


def test_search_respects_hbm():
    res = search(_stages(), CFG, n_devices=256, max_evals=6)
    w = 2.0 * CFG.param_count() / res.tp_c
    assert w <= 0.75 * HW.hbm_bytes


# --------------------------------------------------------------------------
# Runtime (degenerate 1-device dual mesh)
# --------------------------------------------------------------------------
def _smoke_runner(max_len=64):
    scfg = get_smoke("qwen2_0_5b")
    from repro.lm.model import init_params
    params = init_params(scfg, jax.random.PRNGKey(0))
    dual = split_mesh(jax.devices(), 0.5)
    return scfg, DualMeshRunner(scfg, params, dual, max_len=max_len)


def test_runtime_two_streams_and_consistency():
    """The paper's Fig.4b interleave survives as the N=2 / group_size=1
    special case of the continuous-batching runtime."""
    scfg, r = _smoke_runner()
    prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                                scfg.vocab)
    a, b, trace = r.run_two_streams(prompt, prompt, gen_steps=4)
    assert a.shape == (2, 13) and b.shape == (2, 13)
    # identical prompts on identical params -> identical generations
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    kinds = [(k, m) for k, m, _ in trace]
    assert kinds == [("prefill", "c"), ("decode", "p"),
                     ("prefill", "c"), ("decode", "p")]


def test_runtime_nstream_serve_matches_unfused():
    """Fused decode groups (continuous batching) emit exactly the tokens
    the streams would emit alone, across mixed generation lengths,
    chunked prefill, and mid-group eviction."""
    scfg, r = _smoke_runner()
    prompts = [jax.random.randint(k, (2, 8), 0, scfg.vocab)
               for k in jax.random.split(jax.random.PRNGKey(2), 4)]
    gens = [5, 3, 5, 7]
    res = r.serve(prompts, gen_steps=gens, group_size=2,
                  prefill_chunk=4, quantum=2)
    assert [o.shape for o in res.outputs] == [(2, 13), (2, 11), (2, 13),
                                              (2, 15)]
    _, ref = _smoke_runner()
    for p, g, out in zip(prompts, gens, res.outputs):
        solo = ref.serve([p], gen_steps=g, group_size=1)
        np.testing.assert_array_equal(np.asarray(solo.outputs[0]),
                                      np.asarray(out))
