"""Fault-tolerant fleet serving (ISSUE-7): seeded fault injection at
instruction boundaries, executor retry/escalation, router crash recovery
and SLO shedding, and the property that a faulted live run replays
bitwise from its recorded streams + placement log + recovery event log."""
import json
import os
import sys

import jax
import numpy as np
import pytest

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

from test_fleet import _stub_fleet  # noqa: E402

from repro.fleet import (Fault, FaultInjector, FaultPlan,  # noqa: E402
                         InjectedFault, MultiPoolRouter, PoolCrash,
                         RecoveryConfig, Run, WeightedFair, build_cnn_fleet,
                         stream_from_json, stream_signature, stream_to_json)
from repro.serving import (QueueFull, Request, ShedPolicy,  # noqa: E402
                           poisson_arrivals, replay)


# --------------------------------------------------------------------------
# plan schema + generation
# --------------------------------------------------------------------------
def test_fault_plan_json_round_trip(tmp_path):
    plan = FaultPlan(faults=(
        Fault(kind="run_error", pool="p0", slot=2, member="a", times=2),
        Fault(kind="pool_crash", pool="p1", slot=3),
        Fault(kind="send_drop", pool="p0", slot=1),
        Fault(kind="latency", pool="p1", skew_s=0.002)), seed=7)
    path = tmp_path / "plan.json"
    plan.dump(str(path))
    loaded = FaultPlan.load(str(path))
    assert loaded == plan
    assert json.loads(json.dumps(plan.to_json())) == plan.to_json()


def test_fault_plan_rejects_malformed(tmp_path):
    with pytest.raises(ValueError, match="unknown fault kind"):
        Fault(kind="meteor")
    with pytest.raises(ValueError, match="skew_s"):
        Fault(kind="latency", skew_s=0.0)
    with pytest.raises(ValueError, match="JSON object"):
        FaultPlan.from_json([1, 2])
    with pytest.raises(ValueError, match="version"):
        FaultPlan.from_json({"version": 9, "faults": []})
    with pytest.raises(ValueError, match="'faults' list"):
        FaultPlan.from_json({"version": 1})
    with pytest.raises(ValueError, match="unknown fields"):
        FaultPlan.from_json({"version": 1,
                             "faults": [{"kind": "pool_crash", "gpu": 3}]})
    bad = tmp_path / "bad.json"
    bad.write_text("{not json")
    with pytest.raises(ValueError, match="not valid JSON"):
        FaultPlan.load(str(bad))


def test_fault_plan_generate_is_seeded_and_caps_crashes():
    pools = ["p0", "p1"]
    for seed in range(20):
        plan = FaultPlan.generate(seed, pools=pools, members=["a", "b"],
                                  n=4, max_slot=8)
        again = FaultPlan.generate(seed, pools=pools, members=["a", "b"],
                                   n=4, max_slot=8)
        assert plan == again                    # same seed, same plan
        crashes = [f for f in plan.faults if f.kind == "pool_crash"]
        assert len(crashes) <= len(pools) - 1   # a survivor always remains
        assert plan.seed == seed
    assert any(FaultPlan.generate(s, pools=pools, n=4) !=
               FaultPlan.generate(s + 1, pools=pools, n=4)
               for s in range(5))


def test_recovery_config_validation():
    RecoveryConfig()                            # defaults are valid
    with pytest.raises(ValueError, match="max_retries"):
        RecoveryConfig(max_retries=-1)
    with pytest.raises(ValueError, match="backoff_s"):
        RecoveryConfig(backoff_s=-0.1)
    with pytest.raises(ValueError, match="run_timeout_s"):
        RecoveryConfig(run_timeout_s=0.0)
    with pytest.raises(ValueError, match="timeout_strikes"):
        RecoveryConfig(timeout_strikes=0)


# --------------------------------------------------------------------------
# executor: retry, escalation, record stamping
# --------------------------------------------------------------------------
def _one_pool(**kw):
    return _stub_fleet(cores=("c", "p"), names=["a", "b"],
                       policy=WeightedFair(), service_steps=2, **kw)


def test_run_error_retried_and_clean_replay_matches():
    plan = FaultPlan(faults=(
        Fault(kind="run_error", pool="pool0", slot=1, member="a", times=2),))
    fleet = _one_pool()
    fleet.executor.injector = FaultInjector(plan)
    reqs = [Request(i, model="ab"[i % 2]) for i in range(6)]
    res = replay(fleet, reqs, [0] * 6)
    assert res.metrics.completed == 6           # retry absorbed the fault
    assert fleet.executor.retries == 2
    assert max(r.retries for r in fleet.stream) == 2
    # retries ride the JSON schema but stay out of the signature: a clean
    # (injector-free) replay of the faulted recording matches bitwise
    rt = stream_from_json(stream_to_json(fleet.stream, pool="pool0"))
    assert [r.retries for r in rt] == [r.retries for r in fleet.stream]
    fresh = _one_pool()
    res_rep = fresh.executor.replay(
        rt, [Request(i, model="ab"[i % 2]) for i in range(6)], [0] * 6)
    assert res_rep.outputs == res.outputs
    assert stream_signature(fresh.stream) == stream_signature(fleet.stream)
    assert all(r.retries == 0 for r in fresh.stream)


def test_retries_exhausted_escalate_to_pool_crash():
    plan = FaultPlan(faults=(
        Fault(kind="run_error", pool="pool0", slot=0, times=5),))
    fleet = _one_pool()
    fleet.executor.injector = FaultInjector(plan)
    fleet.executor.recovery = RecoveryConfig(max_retries=1)
    fleet.submit(Request(0, model="a"))
    with pytest.raises(PoolCrash, match="still failing after 2 attempts"):
        fleet.step()
    assert fleet.executor.retries == 2


def test_injector_fires_deterministically():
    plan = FaultPlan(faults=(
        Fault(kind="run_error", pool="p0", slot=0, times=1),))
    inj = FaultInjector(plan)
    with pytest.raises(InjectedFault):
        inj.before("p0", Run(member="a"), 0)
    inj.before("p0", Run(member="a"), 0)        # times=1: fires once
    inj.before("p1", Run(member="a"), 0)        # wrong pool: never
    assert inj.summary()["faults"][0]["fired"] == 1


# --------------------------------------------------------------------------
# router: crash recovery, dropped SENDs, degradation
# --------------------------------------------------------------------------
def _mk_router(injector=None, shed=False, **kw):
    def pool():
        f = _stub_fleet(cores=("c", "p"), names=["a", "b"],
                        policy=WeightedFair(), service_steps=2,
                        max_queue=16)
        if shed:                    # member admission -> SLO shedding
            for m in f.members:
                m.engine.policy = ShedPolicy()
        return f
    return MultiPoolRouter({"p0": pool(), "p1": pool()},
                           injector=injector, **kw)


def _statuses(res):
    return {c.ticket.rid: c.metrics.status for c in res.completions}


def test_pool_crash_recovers_unretired_requests_on_survivor():
    plan = FaultPlan(faults=(Fault(kind="pool_crash", pool="p0", slot=2),))
    router = _mk_router(injector=FaultInjector(plan))
    reqs = [Request(i, model="ab"[i % 2]) for i in range(8)]
    for r in reqs:
        router.submit(r)
    res = router.drain()
    # exactly-once: every request retired exactly once, none lost
    assert sorted(c.ticket.rid for c in res.completions) == list(range(8))
    assert router.duplicates_dropped == 0
    assert router.dead == {"p0": router.dead["p0"]}
    assert "injected crash" in router.dead["p0"]
    st = _statuses(res)
    assert set(st.values()) <= {"ok", "recovered"}
    assert "recovered" in st.values()           # p0 held work when it died
    assert any(e[0] == "fail" and e[2] == "p0" for e in router.events)
    assert any(e[0] == "recover" for e in router.events)
    # post-crash submissions avoid the dead pool
    t = router.submit(Request(99, model="a"))
    assert router.placements[-1][1] == "p1"
    assert router.drain().completions[-1].ticket.rid == t.rid


def test_faulted_crash_run_replays_bitwise():
    plan = FaultPlan(faults=(Fault(kind="pool_crash", pool="p0", slot=2),))
    live = _mk_router(injector=FaultInjector(plan))
    reqs = [Request(i, model="ab"[i % 2]) for i in range(8)]
    for r in reqs:
        live.submit(r)
    res_live = live.drain()

    rt = {name: stream_from_json(stream_to_json(recs, pool=name))
          for name, recs in live.streams().items()}
    fresh = _mk_router()                        # no injector attached
    res_rep = fresh.replay(rt, live.placements,
                           [Request(i, model="ab"[i % 2]) for i in range(8)],
                           events=live.events)
    assert stream_signature(fresh.stream()) == \
        stream_signature(live.stream())
    assert fresh.events == live.events
    assert _statuses(res_rep) == _statuses(res_live)
    assert res_rep.outputs == res_live.outputs
    assert fresh.dead.keys() == live.dead.keys()


def test_dropped_send_rerouted_and_replays():
    plan = FaultPlan(faults=(Fault(kind="send_drop", pool="p1", slot=0),))
    live = _mk_router(injector=FaultInjector(plan))
    reqs = [Request(i, model="a") for i in range(6)]
    for r in reqs:
        live.submit(r)
    queued_p1 = live.executors["p1"].fleet.queued
    assert queued_p1 >= 1
    moved = live.migrate("p1", "p0")
    assert moved == 0                           # lost in transit
    assert any(e[0] == "drop" for e in live.events)
    res_live = live.drain()
    st = _statuses(res_live)
    assert sorted(st) == list(range(6))         # nothing lost
    assert list(st.values()).count("recovered") == queued_p1
    assert live.dead == {} and live.duplicates_dropped == 0

    rt = {name: stream_from_json(stream_to_json(recs, pool=name))
          for name, recs in live.streams().items()}
    fresh = _mk_router()
    res_rep = fresh.replay(rt, live.placements,
                           [Request(i, model="a") for i in range(6)],
                           events=live.events)
    assert stream_signature(fresh.stream()) == \
        stream_signature(live.stream())
    assert fresh.events == live.events
    assert _statuses(res_rep) == st
    assert res_rep.outputs == res_live.outputs


def test_timeout_strikes_degrade_pool():
    recovery = RecoveryConfig(run_timeout_s=1e-12, timeout_strikes=2)
    router = _mk_router(recovery=recovery)
    for i in range(8):
        router.submit(Request(i, model="ab"[i % 2]))
    res = router.drain()
    assert res.metrics.completed == 8
    # every RUN beats a 1ps timeout: the first pool over the strike
    # threshold degrades (drained, no longer placed on); its sibling
    # keeps serving because degradation requires a placeable survivor
    assert router.degraded == {"p0"}
    assert router.executors["p0"].timeouts >= 2
    t = router.submit(Request(99, model="a"))
    assert router.placements[-1][1] == "p1"
    assert router.drain().completions[-1].ticket.rid == t.rid


def test_crash_of_sole_server_fails_requests_explicitly():
    # p1 cannot serve model "only0": requests stranded by p0's crash
    # complete as status="failed", never silently vanish
    def pool(names):
        return _stub_fleet(cores=("c", "p")[:len(names)], names=names,
                           policy=WeightedFair(), service_steps=3)
    plan = FaultPlan(faults=(Fault(kind="pool_crash", pool="p0", slot=1),))
    router = MultiPoolRouter({"p0": pool(["only0", "b"]),
                              "p1": pool(["b"])},
                             injector=FaultInjector(plan))
    for i in range(4):
        router.submit(Request(i, model="only0"))
    res = router.drain()
    st = _statuses(res)
    assert sorted(st) == list(range(4))
    assert "failed" in st.values()
    assert all(c.output is None for c in res.completions
               if c.metrics.status == "failed")
    with pytest.raises(KeyError, match="no pool serves"):
        router.submit(Request(9, model="only0"))


def test_replay_reports_pointed_mismatch_not_keyerror():
    # a recovery log claiming p0 died at seq 0 contradicts p0's recorded
    # stream (which keeps retiring work): the offending rid is named in
    # a ValueError, not surfaced as a bare KeyError lookup failure
    live = _mk_router()
    for i in range(4):
        live.submit(Request(i, model="ab"[i % 2]))
    live.drain()
    rt = {name: stream_from_json(stream_to_json(recs, pool=name))
          for name, recs in live.streams().items()}
    fresh = _mk_router()
    with pytest.raises(ValueError, match=r"placement log .*disagree"):
        fresh.replay(rt, live.placements,
                     [Request(i, model="ab"[i % 2]) for i in range(4)],
                     events=[("fail", 0, "p0")])
    fresh2 = _mk_router()
    with pytest.raises(ValueError, match="unknown recovery event kind"):
        fresh2.replay(rt, live.placements,
                      [Request(i, model="ab"[i % 2]) for i in range(4)],
                      events=[("meteor", 0, "p0")])


# --------------------------------------------------------------------------
# SLO shedding + status metrics
# --------------------------------------------------------------------------
def test_shed_policy_validation():
    with pytest.raises(ValueError, match="clock"):
        ShedPolicy(clock="sundial")
    with pytest.raises(ValueError, match="slo_s"):
        ShedPolicy(slo_s=0.0, clock="wall")
    with pytest.raises(ValueError, match="wall-clock"):
        ShedPolicy(slo_s=1.0, clock="slot")


def test_slot_deadline_requests_shed_not_lost():
    from test_fleet import StubEngine

    eng = StubEngine(capacity=1, service_steps=1, policy=ShedPolicy())
    eng._slot = 1                   # StubEngine has no scheduler loop of
    for i, dl in enumerate([None, 0, 99]):      # its own; pin the clock
        eng.submit(Request(i, deadline=dl))
    res = eng.drain()
    st = {c.ticket.rid: c.metrics.status for c in res.completions}
    # capacity 1 admits rid 0 first; rid 1 (deadline slot 0 < clock 1)
    # expires in queue and sheds at admission; rid 2's slack survives
    assert st == {0: "ok", 1: "shed", 2: "ok"}
    assert [c.output for c in res.completions
            if c.metrics.status == "shed"] == [None]
    m = res.metrics
    assert (m.count("shed"), m.count("ok")) == (1, 2)
    assert m.goodput() == 2
    assert res.stats                            # result() stays intact


def test_everything_shed_stays_json_safe():
    from test_fleet import StubEngine

    eng = StubEngine(capacity=1, service_steps=1, policy=ShedPolicy())
    eng.submit(Request(0, model="a", deadline=0))
    eng.submit(Request(1, model="a", deadline=0))
    eng._slot = 5                               # every deadline is past
    res = eng.drain()
    s = res.metrics.summary()
    assert s["shed"] == 2 and s["completed"] == 2   # retired, not lost
    assert s["requests_per_s"] == 0.0               # but zero served
    assert s["goodput_fps"] == 0.0
    assert s["p50_ms"] is None and s["p95_ms"] is None
    assert s["per_model"]["a"]["shed"] == 2
    json.dumps(s)                               # lands in BENCH JSONs


def test_fleet_slot_clock_sheds_deterministically():
    # the fleet executor clocks members with the *fleet* slot before each
    # RUN — live, compiled and replayed runs shed the identical set
    from repro.fleet import compile_fleet, validate_stream

    # member admission policy = ShedPolicy, fleet scheduling policy =
    # WeightedFair
    def build():
        f = _stub_fleet(cores=("c", "p"), names=["a", "b"],
                        policy=WeightedFair(), service_steps=2,
                        capacity=1)
        for m in f.members:
            m.engine.policy = ShedPolicy()
        return f

    reqs = [Request(i, model="a", deadline=3) for i in range(6)]
    arr = [0] * 6
    compiled = compile_fleet(build(), reqs, arr)
    validate_stream(compiled)
    live = build()
    res_live = replay(live, [Request(i, model="a", deadline=3)
                             for i in range(6)], arr)
    st = {c.ticket.rid: c.metrics.status for c in res_live.completions}
    assert sorted(st) == list(range(6))
    assert "shed" in st.values()                # capacity 1, deadline 3
    assert stream_signature(compiled) == stream_signature(live.stream)
    fresh = build()
    res_rep = fresh.executor.replay(
        live.stream, [Request(i, model="a", deadline=3) for i in range(6)],
        arr)
    assert {c.ticket.rid: c.metrics.status
            for c in res_rep.completions} == st


# --------------------------------------------------------------------------
# the property: faulted runs replay bitwise, across seeded plans
# --------------------------------------------------------------------------
def _drive(router, reqs, arrivals, migrate_at=3):
    """Open-loop drive with a forced mid-run migration attempt (so SEND
    faults have a boundary to fire at)."""
    order = sorted(range(len(reqs)), key=lambda i: arrivals[i])
    nxt, step, refused = 0, 0, []
    while nxt < len(order) or refused or router.has_work:
        due, refused = refused, []
        while nxt < len(order) and arrivals[order[nxt]] <= step:
            due.append(order[nxt])
            nxt += 1
        for i in due:
            try:
                router.submit(reqs[i])
            except QueueFull:
                refused.append(i)
        if (step == migrate_at and not router.dead
                and router.executors["p1"].fleet.queued):
            router.migrate("p1", "p0")
        if router.has_work:
            router.step()
        step += 1
    return router.result()


@pytest.mark.parametrize("seed", range(25))
def test_seeded_fault_plans_replay_bitwise(seed):
    """The acceptance property, swept over 25 seeded plans: a faulted
    live run (crashes, injected RUN errors, dropped SENDs, latency skew,
    under slot-deadline shedding) replays bitwise from its recorded
    streams + placements + recovery events — same stream signatures,
    same shed set, same recovered/failed rids, same outputs — with no
    injector attached."""
    n = 12
    plan = FaultPlan.generate(seed, pools=["p0", "p1"],
                              members=["a", "b"], n=3, max_slot=6)
    arrivals = poisson_arrivals(n, rate=2.0, seed=seed)

    def reqs():
        return [Request(i, model="ab"[i % 2],
                        deadline=arrivals[i] + 5 + (i % 3))
                for i in range(n)]

    live = _mk_router(injector=FaultInjector(plan), shed=True)
    res_live = _drive(live, reqs(), arrivals)
    st_live = _statuses(res_live)
    assert sorted(st_live) == list(range(n))    # exactly once, none lost
    assert live.duplicates_dropped == 0

    rt = {name: stream_from_json(stream_to_json(recs, pool=name))
          for name, recs in live.streams().items()}
    fresh = _mk_router(shed=True)
    res_rep = fresh.replay(rt, live.placements, reqs(),
                           events=live.events)
    assert stream_signature(fresh.stream()) == \
        stream_signature(live.stream())
    assert fresh.events == live.events
    assert _statuses(res_rep) == st_live
    assert res_rep.outputs == res_live.outputs
    assert fresh.dead.keys() == live.dead.keys()


@pytest.mark.parametrize("seed", [1, 4])
def test_cnn_pool_crash_recovers_and_replays_bitwise(seed):
    """Real pipeline members: killing one of two single-model CNN pools
    mid-run re-routes its in-flight work to the survivor (with a crash
    REBALANCE re-leasing the survivor's split) and the faulted run
    replays bitwise — output arrays included."""
    def pools():
        e0, _ = build_cnn_fleet(["squeezenet"], use_pallas=False,
                                fuse=False)
        e1, _ = build_cnn_fleet(["squeezenet"], use_pallas=False,
                                fuse=False)
        return {"p0": e0, "p1": e1}

    def reqs():
        keys = jax.random.split(jax.random.PRNGKey(seed), 6)
        return [Request(jax.random.normal(k, (1, 32, 32, 3)),
                        model="squeezenet") for k in keys]

    plan = FaultPlan(faults=(Fault(kind="pool_crash", pool="p0",
                                   slot=1 + seed % 2),), seed=seed)
    live = MultiPoolRouter(pools(), injector=FaultInjector(plan),
                           plan_evals=1)
    for r in reqs():
        live.submit(r)
    res_live = live.drain()
    st = _statuses(res_live)
    assert sorted(st) == list(range(6))
    assert set(st.values()) <= {"ok", "recovered"}
    assert "recovered" in st.values()
    assert list(live.dead) == ["p0"]
    # graceful degradation re-leased theta on the survivor
    assert any(p == "p1" for p, _t in live.rebalances)

    rt = {name: stream_from_json(stream_to_json(recs, pool=name))
          for name, recs in live.streams().items()}
    fresh = MultiPoolRouter(pools(), plan_evals=1)
    res_rep = fresh.replay(rt, live.placements, reqs(),
                           events=live.events)
    assert stream_signature(fresh.stream()) == \
        stream_signature(live.stream())
    assert _statuses(res_rep) == st
    for a, b in zip(res_rep.outputs, res_live.outputs):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# --------------------------------------------------------------------------
# CLI: --faults / --slo-ms validation (exit 2, never a traceback)
# --------------------------------------------------------------------------
def test_serve_fleet_rejects_bad_slo_and_plans(tmp_path):
    from repro.launch import serve

    with pytest.raises(SystemExit) as ei:
        serve.main(["fleet", "--slo-ms", "-5"])
    assert ei.value.code == 2
    with pytest.raises(SystemExit) as ei:
        serve.main(["fleet", "--slo-ms", "0"])
    assert ei.value.code == 2
    bad = tmp_path / "bad.json"
    bad.write_text("{not json")
    with pytest.raises(SystemExit) as ei:
        serve.main(["fleet", "--faults", str(bad)])
    assert ei.value.code == 2
    stale = tmp_path / "stale.json"
    stale.write_text(json.dumps({"version": 99, "faults": []}))
    with pytest.raises(SystemExit) as ei:
        serve.main(["fleet", "--faults", str(stale)])
    assert ei.value.code == 2
    with pytest.raises(SystemExit) as ei:
        serve.main(["fleet", "--faults", str(tmp_path / "missing.json")])
    assert ei.value.code == 2
