"""Fleet serving (ISSUE-5): per-model outputs bitwise-equal to standalone
engines, router fairness under a skewed Poisson mix, per-member QueueFull
isolation, deadline-EDF / priority admission ordering, the planner /
Table-VII cross-check, and the committed BENCH_fleet.json acceptance."""
import json
import os
import sys
import time

import jax
import numpy as np
import pytest

sys.path.insert(0, os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))))            # repo root -> benchmarks pkg

from repro.core.arch import DUAL_MULTI
from repro.core.search import harmonic_mean
from repro.fleet import (DeadlineEDF, DevicePool, FleetEngine, RoundRobin,
                         Router, ShortestQueue, WeightedFair,
                         build_cnn_fleet, make_policy, mix_schedule,
                         normalize_mix, plan_fleet, plan_rows)
from repro.serving import (DeadlineAdmission, Engine, EngineBase,
                           FixedRateAdmission, PriorityAdmission,
                           QueueFull, Request, poisson_arrivals, replay)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# --------------------------------------------------------------------------
# a minimal member engine: the fleet's cross-engine logic under test needs
# queues and slots, not a real network
# --------------------------------------------------------------------------
class StubEngine(EngineBase):
    """Serves any payload in ``service_steps`` slots; declares a fixed
    dominant core so co-dispatch ordering is controllable.  Mirrors the
    CNN engine's two-phase ``advance``/``retire`` split and can record
    its dispatch order into a shared ``trace`` list."""

    def __init__(self, *, capacity=2, service_steps=1, core="c",
                 max_queue=None, policy=None, name=None, trace=None):
        super().__init__(max_queue=max_queue)
        self.policy = policy or FixedRateAdmission(1)
        self.capacity = capacity
        self.service_steps = service_steps
        self._core = core
        self._name = name
        self._trace = trace
        self._flight: list[list] = []       # [remaining, rid, payload]

    @property
    def in_flight(self):
        return len(self._flight)

    @property
    def has_work(self):
        return bool(self._pending or self._flight)

    @property
    def next_core(self):
        return self._core if self.has_work else None

    def advance(self):
        self._start_clock()
        if self._trace is not None:
            self._trace.append(self._name)
        for f in self._flight:
            f[0] -= 1
        finished = [f for f in self._flight if f[0] <= 0]
        self._flight = [f for f in self._flight if f[0] > 0]
        n = self.policy.admit(queued=len(self._pending),
                              in_flight=len(self._flight),
                              capacity=self.capacity)
        for _ in range(max(0, min(n, len(self._pending),
                                  self.capacity - len(self._flight)))):
            popped = self._pop_admission()      # None: the rest was shed
            if popped is None:
                break
            req, _t = popped
            self._metrics[req.rid].started_at = time.perf_counter()
            self._flight.append([self.service_steps, req.rid, req.payload])
        return finished

    def retire(self, finished):
        out = self._take_shed()
        out.extend(self._finish(rid, payload)
                   for _, rid, payload in finished)
        return out

    def step(self):
        return self.retire(self.advance())


def _stub_fleet(cores=("c", "p"), names=None, weights=None, policy=None,
                co_dispatch=None, trace=None, **stub_kw):
    names = names or [f"m{i}" for i in range(len(cores))]
    members = {n: StubEngine(core=c, name=n, trace=trace, **stub_kw)
               for n, c in zip(names, cores)}
    return FleetEngine(members, weights=weights, policy=policy,
                       co_dispatch=co_dispatch)


# --------------------------------------------------------------------------
# pool + router basics
# --------------------------------------------------------------------------
def test_pool_lease_exclusive_and_release():
    pool = DevicePool(jax.devices())
    dual = pool.lease("mobilenet_v1")
    assert dual is pool.dual                 # one shared split, no copies
    assert pool.lease("squeezenet") is dual
    with pytest.raises(ValueError, match="already held"):
        pool.lease("mobilenet_v1")
    pool.release("mobilenet_v1")
    assert pool.lease("mobilenet_v1") is dual
    with pytest.raises(KeyError):
        pool.release("never_leased")
    assert set(pool.stats()["leases"]) == {"mobilenet_v1", "squeezenet"}


def test_router_routes_and_rejects():
    r = Router(["a", "b"])
    assert r.route(Request(0, model="b")) == "b"
    with pytest.raises(KeyError, match="no member serves"):
        r.route(Request(0, model="zzz"))
    with pytest.raises(KeyError, match="untagged"):
        r.route(Request(0))                  # ambiguous in a 2-member fleet
    assert Router(["solo"]).route(Request(0)) == "solo"
    with pytest.raises(ValueError, match="duplicate"):
        Router(["a", "a"])
    with pytest.raises(ValueError, match="unknown scheduling policy"):
        make_policy("nope")


def test_mix_schedule_realizes_shares_deterministically():
    mix = {"a": 0.5, "b": 0.3, "c": 0.2}
    tags = mix_schedule(mix, 10)
    assert tags == mix_schedule(mix, 10)
    assert {t: tags.count(t) for t in mix} == {"a": 5, "b": 3, "c": 2}
    # interleaved, not model-sized bursts: 'a' never waits 3 slots
    assert all("a" in tags[i:i + 3] for i in range(0, 8))
    with pytest.raises(ValueError, match="> 0"):
        normalize_mix({"a": 1.0, "b": 0.0})


# --------------------------------------------------------------------------
# fleet engine mechanics (stub members)
# --------------------------------------------------------------------------
def test_fleet_satisfies_engine_protocol():
    assert isinstance(_stub_fleet(), Engine)


def test_fleet_routes_and_completes_tagged_requests():
    eng = _stub_fleet(cores=("c", "p"), names=["a", "b"])
    for i, m in enumerate(["a", "b", "a"]):
        t = eng.submit(Request(100 + i, model=m))
        assert t.rid == i
    res = eng.drain()
    assert res.outputs == [100, 101, 102]    # fleet submission order
    assert [m.model for m in res.metrics.requests] == ["a", "b", "a"]
    assert res.stats["per_member"]["a"]["completed"] == 2
    assert res.metrics.by_model()["a"]["completed"] == 2
    assert "per_model" in res.metrics.summary()


def test_fleet_co_dispatch_orders_complementary_core_first():
    """A fleet slot dispatches the policy's primary first, then the
    remaining members with the core-complementary one ahead — the
    cross-network Fig.4b ordering — and ``co_dispatch`` bounds the
    slot width (0 = strict policy-only stepping)."""
    trace = []
    eng = _stub_fleet(cores=("c", "c", "p"), names=["a", "b", "p1"],
                      trace=trace)
    for name in ("a", "b", "p1"):
        eng.submit(Request(0, model=name))
    eng.step()
    # primary a (round-robin), then p1 (opposite core), then b
    assert trace == ["a", "p1", "b"]
    assert [m.dispatches for m in eng.members] == [1, 1, 1]
    # bounded width: only the primary + one complementary co-dispatch
    trace2 = []
    eng2 = _stub_fleet(cores=("c", "c", "p"), names=["a", "b", "p1"],
                       trace=trace2, co_dispatch=1)
    for name in ("a", "b", "p1"):
        eng2.submit(Request(0, model=name))
    eng2.step()
    assert trace2 == ["a", "p1"]
    # co_dispatch=0: one member per slot, the policy's pick only
    solo = _stub_fleet(cores=("c", "p"), names=["a", "b"], co_dispatch=0)
    solo.submit(Request(1, model="a"))
    solo.submit(Request(2, model="b"))
    solo.step()
    assert sorted(m.dispatches for m in solo.members) == [0, 1]
    with pytest.raises(ValueError, match="co_dispatch"):
        _stub_fleet(co_dispatch=-1)


def test_burst_advances_consecutive_slots_before_retiring():
    """burst=k advances each batched member k slots back-to-back (the
    locality amortization), retiring once at the end; completions and
    accounting stay exact."""
    trace = []
    eng = _stub_fleet(cores=("c", "p"), names=["a", "b"], trace=trace,
                      capacity=2, service_steps=2)
    eng.burst = 3
    for name in ("a", "a", "b"):
        eng.submit(Request(0, model=name))
    eng.step()
    assert trace == ["a", "a", "a", "b", "b", "b"]
    assert eng._by_name["a"].dispatches == 3
    res = eng.drain()
    assert res.metrics.completed == 3
    with pytest.raises(ValueError, match="burst"):
        FleetEngine({"m": StubEngine()}, burst=0)


def test_backpressure_isolated_per_member_queue():
    """A full member queue raises QueueFull for that model's traffic only,
    and the failed submit leaves no trace in the fleet accounting."""
    eng = _stub_fleet(cores=("c", "p"), names=["a", "b"],
                      capacity=1, service_steps=3, max_queue=1)
    eng.submit(Request(0, model="a"))
    with pytest.raises(QueueFull):
        eng.submit(Request(1, model="a"))    # a's queue is full...
    eng.submit(Request(2, model="b"))        # ...b's is not
    with pytest.raises(QueueFull):
        eng.submit(Request(3, model="b"))    # now b's is full as well
    eng.step()      # c/p-complementary co-dispatch admits both queues
    eng.submit(Request(1, model="a"))        # freed: accepted now
    eng.submit(Request(3, model="b"))
    res = eng.drain()
    assert res.metrics.completed == 4        # only successful submits exist
    assert [c.ticket.rid for c in res.completions] == [0, 1, 2, 3]
    assert res.outputs == [0, 2, 1, 3]       # fleet submission order


def test_replay_retries_through_member_backpressure():
    eng = _stub_fleet(cores=("c", "p"), names=["a", "b"],
                      capacity=1, service_steps=2, max_queue=1)
    reqs = [Request(i, model=("a" if i % 2 == 0 else "b"))
            for i in range(6)]
    res = replay(eng, reqs, [0] * 6)
    assert res.metrics.completed == 6
    assert res.outputs == list(range(6))


def test_replay_queuefull_does_not_block_other_members():
    """A refused submit (member queue full) must not head-of-line block
    same-step traffic for other members: replay retries the refused
    request later but keeps submitting past it."""
    eng = _stub_fleet(cores=("c", "p"), names=["a", "b"],
                      capacity=1, service_steps=4, max_queue=1)
    # two a-requests due at step 0 — the second is refused (a's queue
    # holds one) — then a b-request also due at step 0
    reqs = [Request(0, model="a"), Request(1, model="a"),
            Request(2, model="b")]
    res = replay(eng, reqs, [0, 0, 0])
    assert res.metrics.completed == 3
    # b was admitted at slot 0 alongside a's first request, not behind
    # a's retry: their start stamps precede the refused request's
    m = {r.model: [] for r in res.metrics.requests}
    for r in res.metrics.requests:
        m[r.model].append(r.started_at)
    assert min(m["b"]) < max(m["a"])


def test_weighted_fair_tracks_skewed_mix():
    """Dispatch counts stay within one slot of the weighted entitlement
    while every member has backlog (deficit round-robin), and a skewed
    Poisson trace drains fully."""
    weights = {"a": 0.6, "b": 0.3, "c": 0.1}
    eng = _stub_fleet(cores=("c", "p", "c"), names=list(weights),
                      weights=weights, policy=WeightedFair(),
                      co_dispatch=0, capacity=1, service_steps=2)
    for name in mix_schedule(weights, 30):
        eng.submit(Request(0, model=name))
    steps = 20
    for _ in range(steps):
        eng.step()
    for m in eng.members:
        assert abs(m.dispatches - weights[m.name] * steps) <= 1.0, \
            (m.name, m.dispatches)
    # skewed Poisson arrivals: everything still completes, mix preserved
    eng2 = _stub_fleet(cores=("c", "p", "c"), names=list(weights),
                       weights=weights, policy=WeightedFair(),
                       capacity=2, service_steps=1)
    tags = mix_schedule(weights, 20)
    res = replay(eng2, [Request(i, model=t) for i, t in enumerate(tags)],
                 poisson_arrivals(20, rate=2.0, seed=3))
    assert res.metrics.completed == 20
    assert res.metrics.by_model()["a"]["completed"] == tags.count("a")


def test_weighted_fair_zero_weights_degrade_to_equal_share():
    """All-zero weights must fall back to equal entitlement (alternating
    picks), not collapse to lowest-index-first."""
    from repro.fleet import MemberView

    def view(i, dispatches):
        return MemberView(index=i, name=f"m{i}", queued=1, in_flight=0,
                          weight=0.0, dispatches=dispatches,
                          head_deadline=None, next_core="c", has_work=True)

    wf = WeightedFair()
    picks = []
    counts = [0, 0]
    for t in range(6):
        i = wf.pick([view(0, counts[0]), view(1, counts[1])], t)
        counts[i] += 1
        picks.append(i)
    assert counts == [3, 3]              # equal share, not always m0


def test_round_robin_and_shortest_queue_policies():
    eng = _stub_fleet(cores=("c", "c", "c"), names=["a", "b", "c"],
                      policy=RoundRobin(), co_dispatch=0,
                      capacity=1, service_steps=1)
    for name in ("a", "b", "c"):
        eng.submit(Request(0, model=name))
        eng.submit(Request(1, model=name))
    for _ in range(6):
        eng.step()
    assert [m.dispatches for m in eng.members] == [2, 2, 2]
    sq = _stub_fleet(cores=("c", "c"), names=["big", "small"],
                     policy=ShortestQueue(), co_dispatch=0,
                     capacity=1, service_steps=1)
    for _ in range(4):
        sq.submit(Request(0, model="big"))
    sq.submit(Request(0, model="small"))
    sq.step()                               # least outstanding work first
    assert sq._by_name["small"].dispatches == 1
    assert sq._by_name["big"].dispatches == 0


def test_deadline_edf_orders_admissions_and_members():
    """Member-level DeadlineAdmission admits the earliest deadline first
    (completion order follows deadlines, not submission); fleet-level
    DeadlineEDF steps the member holding the most urgent queued request."""
    m = StubEngine(core="c", capacity=1, service_steps=1,
                   policy=DeadlineAdmission())
    eng = FleetEngine({"m": m}, co_dispatch=0)
    # deadlines deliberately out of submission order; None sorts last
    for payload, dl in [(0, 30.0), (1, 10.0), (2, 20.0), (3, None),
                        (4, 5.0)]:
        eng.submit(Request(payload, deadline=dl))
    finished = []
    while eng.has_work:
        finished.extend(c.output for c in eng.step())
    assert finished == [4, 1, 2, 0, 3]      # EDF admission order
    assert eng.result().outputs == [0, 1, 2, 3, 4]   # submit order kept
    # fleet-level: the member whose head deadline is earliest goes first
    fleet = FleetEngine({"a": StubEngine(core="c"),
                         "b": StubEngine(core="c")},
                        policy=DeadlineEDF(), co_dispatch=0)
    fleet.submit(Request(0, model="a", deadline=20.0))
    fleet.submit(Request(1, model="b", deadline=5.0))
    fleet.step()
    assert fleet._by_name["b"].dispatches == 1
    assert fleet._by_name["a"].dispatches == 0


def test_priority_admission_orders_queue():
    m = StubEngine(core="c", capacity=1, service_steps=1,
                   policy=PriorityAdmission())
    eng = FleetEngine({"m": m}, co_dispatch=0)
    for payload, prio in [(0, 0), (1, 5), (2, 1)]:
        eng.submit(Request(payload, priority=prio))   # untagged: solo member
    finished = []
    while eng.has_work:
        finished.extend(c.output for c in eng.step())
    assert finished == [1, 2, 0]            # high priority first, then FIFO


def test_fleet_admission_map_installs_member_policies():
    members = {"a": StubEngine(core="c"), "b": StubEngine(core="p")}
    edf = DeadlineAdmission()
    FleetEngine(members, admission={"a": edf})
    assert members["a"].policy is edf
    assert isinstance(members["b"].policy, FixedRateAdmission)
    with pytest.raises(KeyError, match="unknown member"):
        FleetEngine({"a": StubEngine()}, admission={"zzz": edf})


# --------------------------------------------------------------------------
# real engines: bitwise parity + shared pool
# --------------------------------------------------------------------------
def test_fleet_outputs_bitwise_equal_standalone_engines():
    """Per-model outputs through the fleet are bitwise-identical to each
    model's standalone engine (same params seed, same step program): the
    fleet multiplexes, it never touches the math."""
    from repro.models.cnn import build_model
    from repro.serving import stream_images

    models = ["mobilenet_v1", "squeezenet"]
    eng, pool = build_cnn_fleet(models, use_pallas=False, fuse=False)
    assert set(pool.stats()["leases"]) == set(models)
    tags = mix_schedule({m: 0.5 for m in models}, 4)
    imgs = [jax.random.normal(k, (1, 32, 32, 3))
            for k in jax.random.split(jax.random.PRNGKey(0), 4)]
    res = replay(eng, [Request(x, model=t) for x, t in zip(imgs, tags)],
                 poisson_arrivals(4, rate=1.0, seed=0))
    assert res.metrics.completed == 4
    by_model: dict[str, list] = {m: [] for m in models}
    for t, x in zip(tags, imgs):
        by_model[t].append(x)
    standalone = {}
    for m in models:
        params, _, graph = build_model(m)
        from repro.core.arch import BoardModel, DUAL_BASELINE
        from repro.core.scheduler import build_schedule
        from repro.dualcore.runtime import DualCoreRunner

        sched = build_schedule(graph, DUAL_BASELINE, BoardModel(),
                               "balanced")
        runner = DualCoreRunner(m, params, sched, use_pallas=False,
                                fuse=False)
        standalone[m] = iter(stream_images(runner, by_model[m]).outputs)
    for t, out in zip(tags, res.outputs):
        np.testing.assert_array_equal(np.asarray(out),
                                      np.asarray(next(standalone[t])))
    # per-model latency breakdown present for every member
    assert set(res.metrics.by_model()) == set(models)


def test_real_engines_co_dispatch_on_shared_pool():
    """With real runners the interleaved fleet issues more member
    dispatches than fleet slots — cross-network groups share slots."""
    eng, _ = build_cnn_fleet(["mobilenet_v1", "squeezenet"],
                             use_pallas=False, fuse=False)
    imgs = [jax.random.normal(k, (1, 32, 32, 3))
            for k in jax.random.split(jax.random.PRNGKey(1), 4)]
    tags = mix_schedule({"mobilenet_v1": 0.5, "squeezenet": 0.5}, 4)
    for x, t in zip(imgs, tags):
        eng.submit(Request(x, model=t))
    res = eng.drain()
    assert res.stats["dispatches"] > res.stats["slots"]
    assert res.metrics.completed == 4


@pytest.mark.slow
def test_fleet_with_lm_member():
    """LM + CNN mix: a DualMeshEngine rides alongside a DualCoreEngine
    behind the same fleet front end."""
    from repro.configs.registry import get_smoke
    from repro.core.arch import BoardModel, DUAL_BASELINE
    from repro.core.scheduler import build_schedule
    from repro.dualcore.runtime import DualCoreRunner
    from repro.dualmesh import DualMeshRunner, split_mesh
    from repro.lm.model import init_params
    from repro.models.cnn import build_model
    from repro.serving import DualCoreEngine, DualMeshEngine

    cfg = get_smoke("qwen2_0_5b")
    lm = DualMeshEngine(DualMeshRunner(cfg, init_params(
        cfg, jax.random.PRNGKey(0)), split_mesh(jax.devices(), 0.5),
        max_len=16), group_size=1)
    params, _, graph = build_model("squeezenet")
    sched = build_schedule(graph, DUAL_BASELINE, BoardModel(), "balanced")
    cnn = DualCoreEngine(DualCoreRunner("squeezenet", params, sched,
                                        use_pallas=False, fuse=False))
    eng = FleetEngine({"lm": lm, "squeezenet": cnn})
    prompt = jax.random.randint(jax.random.PRNGKey(1), (1, 4), 0,
                                cfg.vocab)
    img = jax.random.normal(jax.random.PRNGKey(2), (1, 32, 32, 3))
    eng.submit(Request(prompt, gen_steps=2, model="lm"))
    eng.submit(Request(img, model="squeezenet"))
    res = eng.drain()
    assert res.metrics.completed == 2
    assert res.outputs[0].shape == (1, 6)      # prompt + 2 generated
    assert res.outputs[1].shape == (1, 1000)
    assert set(res.metrics.by_model()) == {"lm", "squeezenet"}


# --------------------------------------------------------------------------
# planner + Table VII cross-check + committed bench acceptance
# --------------------------------------------------------------------------
def test_weighted_harmonic_mean_is_mix_aggregate():
    fps = [100.0, 400.0]
    # 50/50 mix: each unit of work is 0.5/100 + 0.5/400 seconds
    assert harmonic_mean(fps, [0.5, 0.5]) == pytest.approx(160.0)
    assert harmonic_mean(fps) == pytest.approx(160.0)      # unweighted ==
    assert harmonic_mean(fps, [1.0, 0.0]) == pytest.approx(100.0)
    with pytest.raises(ValueError, match="weights"):
        harmonic_mean(fps, [0.5])
    with pytest.raises(ValueError, match="weights"):
        harmonic_mean(fps, [-1.0, 2.0])


def test_plan_fleet_fixed_config_predictions():
    mix = {"mobilenet_v1": 0.5, "squeezenet": 0.5}
    plan = plan_fleet(mix, config=DUAL_MULTI)
    assert plan.config is DUAL_MULTI
    assert sum(plan.mix.values()) == pytest.approx(1.0)
    agg = harmonic_mean([plan.fps[m] for m in plan.mix],
                        [plan.mix[m] for m in plan.mix])
    assert plan.aggregate_fps == pytest.approx(agg)
    # served shares realize the mix exactly
    for m, s in plan.mix.items():
        assert plan.predicted[m] == pytest.approx(s * plan.aggregate_fps)
    assert sum(plan.predicted.values()) == \
        pytest.approx(plan.aggregate_fps)


def test_build_cnn_fleet_realises_plan_theta():
    """The pool split must use the planned Eq.10 theta, not the default —
    on a multi-device mesh the c/p chip ratio IS the planned config."""
    plan = plan_fleet({"squeezenet": 1.0}, config=DUAL_MULTI)
    eng, pool = build_cnn_fleet(["squeezenet"], plan=plan,
                                use_pallas=False, fuse=False)
    assert pool.theta == plan.theta
    assert eng.members[0].engine.runner.schedule is \
        plan.schedules["squeezenet"]


def test_paper_table_vii_fleet_matches_planner():
    """The Table-VII-style rows printed by benchmarks/paper_tables.py are
    exactly fleet.planner.plan_rows of a live plan (ISSUE-5 satellite)."""
    from benchmarks.paper_tables import FLEET_MIX, table_vii_fleet

    rows = table_vii_fleet(config=DUAL_MULTI,
                           measured_path="/nonexistent.json")
    plan = plan_fleet(FLEET_MIX, config=DUAL_MULTI)
    assert rows == plan_rows(plan)
    assert rows[-1][0] == "aggregate"
    assert rows[-1][3] == pytest.approx(plan.aggregate_fps)


def test_committed_fleet_bench_meets_acceptance():
    """The committed BENCH_fleet.json must show the ISSUE-5 acceptance:
    fleet aggregate fps >= the best sequential one-engine-at-a-time
    baseline on the same host (and the gated fields must be present)."""
    with open(os.path.join(REPO, "BENCH_fleet.json")) as f:
        rep = json.load(f)
    fleet, base = rep["fleet"], rep["baseline"]
    assert base["best_fps"] == pytest.approx(
        max(base["engine_at_a_time_fps"], base["run_sequential_fps"]))
    assert fleet["aggregate_fps"] >= base["best_fps"]
    assert rep["fleet_vs_baseline"] >= 1.0
    assert set(rep["mix"]) == set(fleet["per_model"])
    for m in rep["mix"]:
        assert {"p50_ms", "p95_ms"} <= set(fleet["latency"][m])
