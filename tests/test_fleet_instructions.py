"""Instruction-stream fleet execution (ISSUE-6): schema round-trips,
compile-vs-live bitwise parity, PoolExecutor replay, cross-pool
migration + REBALANCE through the MultiPoolRouter, and the Chrome-tracing
export."""
import json
import os
import sys
import time

import jax
import numpy as np
import pytest

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))))            # repo root -> benchmarks pkg

from test_fleet import StubEngine, _stub_fleet  # noqa: E402

from repro.fleet import (DevicePool, ExecRecord, FleetEngine,  # noqa: E402
                         Free,
                         MultiPoolRouter, Rebalance, Recv, RoundRobin, Run,
                         SCHEMA_VERSION, Send, WeightedFair, build_cnn_fleet,
                         compile_fleet, dump_stream, load_stream,
                         mix_schedule, stream_from_json, stream_signature,
                         stream_to_json, validate_stream)
from repro.fleet.compiler import CompileError  # noqa: E402
from repro.fleet.trace import chrome_trace  # noqa: E402
from repro.serving import (EngineBase, Request, poisson_arrivals,  # noqa: E402
                           replay)


# --------------------------------------------------------------------------
# instruction schema
# --------------------------------------------------------------------------
def test_instruction_json_round_trip():
    from repro.fleet.instructions import instr_from_dict, instr_to_dict

    for instr in (Run(member="a", slots=3, core="c", primary=True),
                  Run(member="lm", fused=True),
                  Free(member="a"),
                  Send(peer="pool1", member="a", count=2),
                  Send(peer="pool1"),              # member/count wildcards
                  Recv(peer="pool0", count=3),
                  Rebalance(theta=0.25)):
        wire = json.loads(json.dumps(instr_to_dict(instr)))
        assert instr_from_dict(wire) == instr


def test_instruction_schema_rejects_drift():
    from repro.fleet.instructions import instr_from_dict

    with pytest.raises(ValueError, match="unknown fleet instruction op"):
        instr_from_dict({"op": "HALT"})
    with pytest.raises(ValueError, match="schema drift"):
        instr_from_dict({"op": "RUN", "member": "a", "gpu": 1})
    with pytest.raises(ValueError, match="schema version"):
        stream_from_json({"version": SCHEMA_VERSION + 1, "records": []})


def test_stream_dump_load_round_trip(tmp_path):
    records = [ExecRecord(instr=Run(member="a", slots=2, core="c",
                                    primary=True),
                          slot=0, seq=0, advances=2, t0=1.0, t1=1.5),
               ExecRecord(instr=Free(member="a"), slot=0, seq=1,
                          advances=0, t0=1.5, t1=1.6),
               # compiled-only records carry no wall-clock stamps
               ExecRecord(instr=Rebalance(theta=0.4), slot=1, seq=2)]
    path = tmp_path / "stream.json"
    dump_stream(records, str(path), pool="pool7")
    with open(path) as f:
        doc = json.load(f)
    assert doc["version"] == SCHEMA_VERSION
    assert doc["pool"] == "pool7"
    loaded = load_stream(str(path))
    assert stream_signature(loaded) == stream_signature(records)
    assert [(r.t0, r.t1) for r in loaded] == \
        [(r.t0, r.t1) for r in records]


def test_validate_stream_invariants():
    ok = [ExecRecord(instr=Run(member="a"), slot=0, seq=0),
          ExecRecord(instr=Free(member="a"), slot=0, seq=1),
          ExecRecord(instr=Run(member="a"), slot=1, seq=2)]
    validate_stream(ok)                      # FREE then next-slot RUN: fine
    with pytest.raises(ValueError, match="slot went backwards"):
        validate_stream([ExecRecord(instr=Run(member="a"), slot=1, seq=0),
                         ExecRecord(instr=Run(member="a"), slot=0, seq=1)])
    with pytest.raises(ValueError, match="seq not strictly increasing"):
        validate_stream([ExecRecord(instr=Run(member="a"), slot=0, seq=0),
                         ExecRecord(instr=Run(member="a"), slot=0, seq=0)])
    with pytest.raises(ValueError, match="dispatch must precede"):
        validate_stream([ExecRecord(instr=Free(member="a"), slot=0, seq=0),
                         ExecRecord(instr=Run(member="b"), slot=0, seq=1)])


# --------------------------------------------------------------------------
# compile-vs-live parity + replay (stub members)
# --------------------------------------------------------------------------
_WEIGHTS = {"a": 0.5, "b": 0.3, "c": 0.2}


def _mk(trace=None):
    return _stub_fleet(cores=("c", "p", "c"), names=list(_WEIGHTS),
                       weights=_WEIGHTS, policy=WeightedFair(),
                       trace=trace, capacity=2, service_steps=2,
                       max_queue=2)


def _reqs(n=12):
    return [Request(i, model=t)
            for i, t in enumerate(mix_schedule(_WEIGHTS, n))]


def test_compiled_stream_matches_live_and_replays_bitwise():
    """The tentpole property: compile_fleet's ahead-of-time stream equals
    the live fleet's recorded stream decision-for-decision, and replaying
    it through a fresh fleet's PoolExecutor reproduces the dispatch trace
    and outputs bitwise."""
    arr = poisson_arrivals(12, rate=1.5, seed=1)   # exercises QueueFull
    compiled = compile_fleet(_mk(), _reqs(), arr)  # retries mid-stream
    validate_stream(compiled)

    trace_live = []
    live = _mk(trace_live)
    res_live = replay(live, _reqs(), arr)
    assert res_live.metrics.completed == 12
    assert stream_signature(compiled) == stream_signature(live.stream)

    # serialize -> deserialize -> replay on a fresh fleet
    rt = stream_from_json(stream_to_json(compiled, pool="pool0"))
    trace_rep = []
    fresh = _mk(trace_rep)
    res_rep = fresh.executor.replay(rt, _reqs(), arr)
    assert trace_rep == trace_live
    assert res_rep.outputs == res_live.outputs
    assert stream_signature(fresh.stream) == stream_signature(live.stream)
    assert [c.ticket.rid for c in res_rep.completions] == \
        [c.ticket.rid for c in res_live.completions]


def test_compile_does_not_consume_live_policy_state():
    """Stateful policies (RoundRobin's cursor) are deep-copied by the
    compiler: compiling must not perturb the live fleet's subsequent
    decisions."""
    fleet = _stub_fleet(cores=("c", "p"), names=["a", "b"],
                        policy=RoundRobin(), co_dispatch=0,
                        capacity=1, service_steps=1)
    reqs = [Request(i, model="ab"[i % 2]) for i in range(6)]
    compiled = compile_fleet(fleet, reqs)
    again = compile_fleet(fleet, reqs)
    assert stream_signature(compiled) == stream_signature(again)
    res = replay(fleet, reqs, [0] * 6)       # live run after compiling
    assert res.metrics.completed == 6
    assert stream_signature(fleet.stream) == stream_signature(compiled)


def test_replay_rejects_streams_for_other_traces():
    compiled = compile_fleet(_mk(), _reqs(4))
    fresh = _mk()
    with pytest.raises(ValueError, match="instruction stream exhausted"):
        fresh.executor.replay(compiled, _reqs(8))   # twice the traffic


# --------------------------------------------------------------------------
# opaque members: fused RUN, and the AOT compile refusal
# --------------------------------------------------------------------------
class OpaqueStub(EngineBase):
    """A bare ``step()`` engine (no advance/retire split): serves one
    queued request per step — the shape of the LM ``DualMeshEngine``."""

    @property
    def in_flight(self):
        return 0

    @property
    def has_work(self):
        return bool(self._pending)

    def step(self):
        self._start_clock()
        if not self._pending:
            return []
        req, _t = self._pop_admission()
        self._metrics[req.rid].started_at = time.perf_counter()
        return [self._finish(req.rid, req.payload)]


def test_opaque_member_runs_fused_and_rejects_aot_compile():
    def mk():
        return FleetEngine({"op": OpaqueStub(),
                            "b": StubEngine(core="p", name="b")})

    fleet = mk()
    with pytest.raises(CompileError, match="opaque"):
        compile_fleet(fleet, [Request(0, model="op")])
    fleet.submit(Request(10, model="op"))
    fleet.submit(Request(11, model="b"))
    res = fleet.drain()
    assert res.outputs == [10, 11]
    # the slot lowered to: pure RUN b, fused RUN op, FREE b — the fused
    # dispatch lands after every pure dispatch, before the deferrable FREE
    kinds = [(r.instr.op, getattr(r.instr, "fused", None), r.instr.member)
             for r in fleet.stream if r.slot == 0]
    assert kinds == [("RUN", False, "b"), ("RUN", True, "op"),
                     ("FREE", None, "b")]
    # ...and the recorded stream (the CompileError's pointer) replays
    fresh = mk()
    res2 = fresh.executor.replay(fleet.stream,
                                 [Request(10, model="op"),
                                  Request(11, model="b")])
    assert res2.outputs == res.outputs
    assert stream_signature(fresh.stream) == stream_signature(fleet.stream)


# --------------------------------------------------------------------------
# withdraw_pending (the SEND half of migration)
# --------------------------------------------------------------------------
def test_engine_withdraw_pending_takes_newest_first():
    eng = StubEngine(capacity=1)
    for p in (10, 11, 12):
        eng.submit(Request(p))
    taken = eng.withdraw_pending(2)
    # newest two leave (oldest stays closest to admission), order kept
    assert [req.payload for _, req in taken] == [11, 12]
    assert eng.queued == 1
    rids = [rid for rid, _ in taken]
    assert all(rid not in eng._metrics for rid in rids)
    assert eng.drain().outputs == [10]       # withdrawn leave no trace


def test_fleet_withdraw_pending_unaccounts_and_restores_route():
    fleet = _stub_fleet(cores=("c", "p"), names=["a", "b"], capacity=1,
                        service_steps=3)
    for i, m in enumerate(["a", "a", "a", "b"]):
        fleet.submit(Request(i, model=m))
    pairs = fleet.withdraw_pending(member="a")
    assert [req.payload for _, req in pairs] == [0, 1, 2]
    for frid, req in pairs:
        assert req.rid is None               # fleet identity stripped...
        assert req.model == "a"              # ...route preserved
        assert frid not in fleet._metrics
    with pytest.raises(KeyError, match="no member"):
        fleet.withdraw_pending(member="zzz")
    res = fleet.drain()                      # only b's request remains
    assert res.metrics.completed == 1
    # the withdrawn requests re-submit cleanly elsewhere (the RECV half)
    other = _stub_fleet(cores=("c", "p"), names=["a", "b"])
    for _, req in pairs:
        other.submit(req)
    assert other.drain().outputs == [0, 1, 2]


def test_pool_revoke_all_and_resplit():
    pool = DevicePool(jax.devices())
    pool.lease("mobilenet_v1")
    pool.lease("squeezenet")
    with pytest.raises(RuntimeError, match="leases held"):
        pool.resplit(0.25)
    assert pool.revoke_all() == ["mobilenet_v1", "squeezenet"]
    assert pool.stats()["leases"] == []
    dual = pool.resplit(0.25)
    assert pool.theta == 0.25
    assert pool.lease("squeezenet") is dual   # leasing works again


def test_metrics_zero_completions_stay_json_safe():
    eng = StubEngine(service_steps=5)
    eng.submit(Request(0, model="a"))
    eng.step()                               # started, nothing completes
    m = eng.result().metrics
    s = m.summary()
    assert s["completed"] == 0
    assert s["p50_ms"] is None and s["p95_ms"] is None
    assert s["requests_per_s"] == 0.0
    assert m.by_model() == {}                # nothing completed, no rows
    json.dumps(s)                            # lands in BENCH JSONs as-is
    # and with the clock never started at all
    s0 = StubEngine().result().metrics.summary()
    assert (s0["requests_per_s"], s0["p50_ms"]) == (0.0, None)
    json.dumps(s0)


# --------------------------------------------------------------------------
# multi-pool router: placement, migration, replay
# --------------------------------------------------------------------------
def _mk_router(**kw):
    def pool():
        return _stub_fleet(cores=("c", "p"), names=["a", "b"],
                           policy=WeightedFair(), service_steps=2)
    return MultiPoolRouter({"p0": pool(), "p1": pool()}, **kw)


def test_multipool_places_serves_and_drains_a_pool():
    router = _mk_router()
    reqs = [Request(i, model="ab"[i % 2]) for i in range(8)]
    for r in reqs[:6]:
        router.submit(r)
    router.step()
    moved = router.drain_pool("p1")          # evacuate p1's queue
    assert moved >= 1
    for r in reqs[6:]:
        router.submit(r)
    res = router.drain()
    assert res.metrics.completed == 8
    assert res.outputs == list(range(8))     # router submission order
    st = res.stats
    assert st["engine"] == "multipool"
    assert set(st["pools"]) == {"p0", "p1"}
    assert st["in_transit"] == 0
    assert sum(sum(p["served"].values())
               for p in st["pools"].values()) == 8
    with pytest.raises(KeyError, match="no pool serves"):
        router.submit(Request(0, model="zzz"))
    with pytest.raises(ValueError, match="itself"):
        router.migrate("p0", "p0")


def test_multipool_replay_round_trip_bitwise():
    """The multi-pool acceptance round-trip: record a 2-pool run with a
    forced mid-run migration, serialize the per-pool streams, and re-run
    the (streams, placements) recipe on a fresh router — the re-executed
    streams and every output must come back bitwise-identical."""
    def run_live():
        router = _mk_router()
        reqs = [Request(i, model="ab"[i % 2]) for i in range(10)]
        for r in reqs[:6]:
            router.submit(r)
        router.step()
        router.step()
        router.migrate("p1", "p0")
        for r in reqs[6:]:
            router.submit(r)
        return router, router.drain()

    live, res_live = run_live()
    assert res_live.metrics.completed == 10
    sig_live = stream_signature(live.stream())

    rt = {name: stream_from_json(stream_to_json(recs, pool=name))
          for name, recs in live.streams().items()}
    fresh = _mk_router()
    res_rep = fresh.replay(rt, live.placements,
                           [Request(i, model="ab"[i % 2])
                            for i in range(10)])
    assert res_rep.metrics.completed == 10
    assert stream_signature(fresh.stream()) == sig_live
    assert res_rep.outputs == res_live.outputs
    assert [c.ticket.rid for c in res_rep.completions] == \
        [c.ticket.rid for c in res_live.completions]


def test_multipool_replay_rejects_mismatched_recipe():
    router = _mk_router()
    with pytest.raises(KeyError, match="unknown pools"):
        router.replay({"nope": []}, [], [])
    with pytest.raises(ValueError, match="placements"):
        router.replay({"p0": []}, [(0, "p0")], [])


def test_multipool_drift_check_skips_poolless_fleets():
    # stub fleets hold no DevicePool: the drift detector must pass over
    # them instead of attempting a REBALANCE they cannot execute
    router = _mk_router(rebalance_drift=0.0, rebalance_every=1)
    for i in range(4):
        router.submit(Request(i, model="ab"[i % 2]))
    res = router.drain()
    assert res.metrics.completed == 4
    assert router.rebalances == []


# --------------------------------------------------------------------------
# real CNN engines: compile / replay / rebalance, bitwise
# --------------------------------------------------------------------------
_MODELS = ["mobilenet_v1", "squeezenet"]


def _cnn_fleet():
    return build_cnn_fleet(_MODELS, use_pallas=False, fuse=False)


def _cnn_requests(n=4, seed=0):
    tags = mix_schedule({m: 0.5 for m in _MODELS}, n)
    keys = jax.random.split(jax.random.PRNGKey(seed), n)
    return [Request(jax.random.normal(k, (1, 32, 32, 3)), model=t)
            for k, t in zip(keys, tags)]


def test_cnn_fleet_compile_and_replay_bitwise():
    """Real pipeline members: the AOT-compiled stream matches the live
    run's, and replaying its JSON round-trip on a fresh fleet reproduces
    every output array bitwise (the single-pool acceptance)."""
    arr = poisson_arrivals(4, rate=1.0, seed=0)
    live, _ = _cnn_fleet()
    compiled = compile_fleet(live, _cnn_requests(), arr)
    validate_stream(compiled)
    res_live = replay(live, _cnn_requests(), arr)
    assert res_live.metrics.completed == 4
    assert stream_signature(compiled) == stream_signature(live.stream)

    rt = stream_from_json(stream_to_json(compiled, pool="pool0"))
    fresh, _ = _cnn_fleet()
    res_rep = fresh.executor.replay(rt, _cnn_requests(), arr)
    assert res_rep.metrics.completed == 4
    for a, b in zip(res_rep.outputs, res_live.outputs):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_cnn_fleet_rebalance_mid_run_replays_bitwise():
    """A REBALANCE recorded mid-run (revoke -> resplit -> re-lease ->
    relocate params and in-flight envs) must replay like any other
    instruction: same completions, same output arrays."""
    def run(fleet):
        for r in _cnn_requests(4, seed=2):
            fleet.submit(r)
        fleet.step()
        fleet.step()                         # work now in flight
        fleet.executor.inject(Rebalance(theta=0.7))
        return fleet.drain()

    live, pool = _cnn_fleet()
    res_live = run(live)
    assert res_live.metrics.completed == 4
    assert pool.theta == 0.7
    assert set(pool.stats()["leases"]) == set(_MODELS)  # re-leased
    assert any(isinstance(r.instr, Rebalance) for r in live.stream)

    rt = stream_from_json(stream_to_json(live.stream))
    fresh, fresh_pool = _cnn_fleet()
    res_rep = fresh.executor.replay(rt, _cnn_requests(4, seed=2))
    assert res_rep.metrics.completed == 4
    assert fresh_pool.theta == 0.7
    for a, b in zip(res_rep.outputs, res_live.outputs):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_multipool_cnn_migration_rebalance_parity_vs_standalone():
    """The 2-pool acceptance: a run with a forced migration and one
    REBALANCE completes every admitted request, and each request's output
    is bitwise what its model's standalone engine computes."""
    from repro.core.arch import BoardModel, DUAL_BASELINE
    from repro.core.scheduler import build_schedule
    from repro.dualcore.runtime import DualCoreRunner
    from repro.models.cnn import build_model
    from repro.serving import stream_images

    e0, _ = _cnn_fleet()
    e1, _ = build_cnn_fleet(["squeezenet"], use_pallas=False, fuse=False)
    router = MultiPoolRouter({"p0": e0, "p1": e1})
    reqs = _cnn_requests(6, seed=3)
    for r in reqs:
        router.submit(r)
    assert router.queued == 6
    moved = router.drain_pool("p1")          # force the migration leg
    assert moved >= 1
    theta = router.rebalance(
        "p0", mix={m: 0.5 for m in _MODELS}, theta=0.6)
    assert theta == 0.6
    res = router.drain()
    assert res.metrics.completed == 6
    assert res.stats["rebalances"] == [{"pool": "p0", "theta": 0.6}]
    assert any(isinstance(r.instr, Send) for r in router.stream())
    assert any(isinstance(r.instr, Recv) for r in router.stream())

    by_model = {m: [] for m in _MODELS}
    for r in reqs:
        by_model[r.model].append(r.payload)
    standalone = {}
    for m in _MODELS:
        params, _, graph = build_model(m)
        sched = build_schedule(graph, DUAL_BASELINE, BoardModel(),
                               "balanced")
        runner = DualCoreRunner(m, params, sched, use_pallas=False,
                                fuse=False)
        standalone[m] = iter(stream_images(runner, by_model[m]).outputs)
    for r, out in zip(reqs, res.outputs):    # router submission order
        np.testing.assert_array_equal(
            np.asarray(out), np.asarray(next(standalone[r.model])))


@pytest.mark.slow
def test_multipool_lm_cnn_round_trip_bitwise():
    """The mixed-modality acceptance round-trip: a 2-pool fleet with an
    LM member (opaque -> fused RUNs) next to CNN members, with a forced
    cross-pool migration — record, serialize, replay on fresh pools,
    outputs bitwise."""
    from repro.configs.registry import get_smoke
    from repro.core.arch import BoardModel, DUAL_BASELINE
    from repro.core.scheduler import build_schedule
    from repro.dualcore.runtime import DualCoreRunner
    from repro.dualmesh import DualMeshRunner, split_mesh
    from repro.lm.model import init_params
    from repro.models.cnn import build_model
    from repro.serving import DualCoreEngine, DualMeshEngine

    cfg = get_smoke("qwen2_0_5b")

    def cnn():
        params, _, graph = build_model("squeezenet")
        sched = build_schedule(graph, DUAL_BASELINE, BoardModel(),
                               "balanced")
        return DualCoreEngine(DualCoreRunner(
            "squeezenet", params, sched, use_pallas=False, fuse=False))

    def pools():
        lm = DualMeshEngine(DualMeshRunner(
            cfg, init_params(cfg, jax.random.PRNGKey(0)),
            split_mesh(jax.devices(), 0.5), max_len=16), group_size=1)
        return {"p0": FleetEngine({"lm": lm, "squeezenet": cnn()}),
                "p1": FleetEngine({"squeezenet": cnn()})}

    prompt = jax.random.randint(jax.random.PRNGKey(1), (1, 4), 0,
                                cfg.vocab)
    imgs = [jax.random.normal(k, (1, 32, 32, 3))
            for k in jax.random.split(jax.random.PRNGKey(2), 4)]

    def reqs():
        return [Request(prompt, gen_steps=2, model="lm")] + \
            [Request(x, model="squeezenet") for x in imgs]

    def run_live():
        router = MultiPoolRouter(pools())
        for r in reqs():
            router.submit(r)
        moved = router.drain_pool("p1")      # force SEND/RECV mid-run
        assert moved >= 1
        return router, router.drain()

    live, res_live = run_live()
    assert res_live.metrics.completed == 5
    assert res_live.outputs[0].shape == (1, 6)   # prompt + 2 generated
    fused = [r for r in live.stream()
             if isinstance(r.instr, Run) and r.instr.fused]
    assert fused and all(r.instr.member == "lm" for r in fused)

    rt = {name: stream_from_json(stream_to_json(recs, pool=name))
          for name, recs in live.streams().items()}
    fresh = MultiPoolRouter(pools())
    res_rep = fresh.replay(rt, live.placements, reqs())
    assert res_rep.metrics.completed == 5
    assert stream_signature(fresh.stream()) == \
        stream_signature(live.stream())
    for a, b in zip(res_rep.outputs, res_live.outputs):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# --------------------------------------------------------------------------
# Chrome-tracing export
# --------------------------------------------------------------------------
def _executed_stub_stream():
    trace = []
    fleet = _mk(trace)
    replay(fleet, _reqs(6), [0] * 6)
    return fleet.stream


def test_chrome_trace_tracks_and_events():
    records = _executed_stub_stream()
    doc = chrome_trace({"poolA": records})
    events = doc["traceEvents"]
    meta = [e for e in events if e["ph"] == "M"]
    assert {"name": "poolA"} in [e["args"] for e in meta
                                 if e["name"] == "process_name"]
    tracks = [e["args"]["name"] for e in meta if e["name"] == "thread_name"]
    assert tracks == ["c-submesh", "p-submesh", "retire", "control",
                      "bubbles"]
    slices = [e for e in events if e["ph"] == "X"
              and e["cat"] != "bubble"]
    assert len(slices) == len(records)       # every record is stamped
    assert all(e["ts"] >= 0 and e["dur"] > 0 for e in slices)
    # a RUN on a c-dominant member files under the c-submesh track (0),
    # FREEs under retire (2)
    by_cat = {e["cat"] for e in slices}
    assert {"RUN", "FREE"} <= by_cat
    for r, e in zip(records, slices):
        if isinstance(r.instr, Free):
            assert e["tid"] == 2
    json.dumps(doc)
    # compiled-only records (no stamps) are skipped, not exported at 0
    compiled = compile_fleet(_mk(), _reqs(4))
    assert chrome_trace({"p": compiled})["traceEvents"] == \
        [e for e in chrome_trace({"p": compiled})["traceEvents"]
         if e["ph"] == "M"]


def test_trace_export_cli(tmp_path, capsys):
    from benchmarks import trace_export

    p0 = tmp_path / "s0.json"
    p1 = tmp_path / "s1.json"
    dump_stream(_executed_stub_stream(), str(p0), pool="pool0")
    dump_stream(_executed_stub_stream(), str(p1), pool="pool1")
    out = tmp_path / "trace.json"
    rc = trace_export.main([str(p0), str(p1), "-o", str(out)])
    assert rc == 0
    assert "2 pool(s)" in capsys.readouterr().out
    with open(out) as f:
        doc = json.load(f)
    names = {e["args"]["name"] for e in doc["traceEvents"]
             if e.get("name") == "process_name"}
    assert names == {"pool0", "pool1"}
    # colliding pool names: usage error, exit 2
    dup = tmp_path / "dup.json"
    dump_stream(_executed_stub_stream(), str(dup), pool="pool0")
    with pytest.raises(SystemExit) as ei:
        trace_export.main([str(p0), str(dup), "-o", str(out)])
    assert ei.value.code == 2
    # a compiled-only stream has no wall clock to draw: usage error
    cold = tmp_path / "cold.json"
    dump_stream(compile_fleet(_mk(), _reqs(4)), str(cold), pool="aot")
    with pytest.raises(SystemExit) as ei:
        trace_export.main([str(cold), "-o", str(out)])
    assert ei.value.code == 2


def test_trace_export_reports_partial_skips(tmp_path, capsys):
    """A stream mixing stamped and compiled-only records exports the
    stamped ones and *reports* the skip count instead of silently
    thinning the timeline."""
    from benchmarks import trace_export

    records = _executed_stub_stream() + compile_fleet(_mk(), _reqs(4))
    n_cold = sum(1 for r in records if r.t0 is None)
    assert n_cold > 0
    p = tmp_path / "mixed.json"
    dump_stream(records, str(p), pool="pool0")
    out = tmp_path / "trace.json"
    assert trace_export.main([str(p), "-o", str(out)]) == 0
    text = capsys.readouterr().out
    assert f"skipped {n_cold} compiled-only" in text


def test_chrome_trace_empty_and_recordless_streams():
    doc = chrome_trace({})
    assert doc["traceEvents"] == []
    doc = chrome_trace({"p0": []})
    assert all(e["ph"] == "M" for e in doc["traceEvents"])
    json.dumps(doc)


def test_chrome_trace_control_track_and_pool_row_order():
    from repro.fleet.instructions import SetParam

    mk = [ExecRecord(instr=SetParam(member="a", param="weight", value=2.0),
                     slot=0, seq=0, advances=0, t0=1.0, t1=1.1),
          ExecRecord(instr=Rebalance(theta=0.3), slot=1, seq=1,
                     advances=0, t0=1.1, t1=1.2)]
    # pools are assigned process rows in sorted-name order regardless of
    # dict insertion order
    doc = chrome_trace({"pZ": list(mk), "pA": list(mk)})
    rows = {e["pid"]: e["args"]["name"] for e in doc["traceEvents"]
            if e["name"] == "process_name"}
    assert rows == {0: "pA", 1: "pZ"}
    control = [e for e in doc["traceEvents"]
               if e["ph"] == "X" and e["cat"] != "bubble"]
    assert control and all(e["tid"] == 3 for e in control)
    assert {e["cat"] for e in control} == {"SET_PARAM", "REBALANCE"}


def test_chrome_trace_roofline_args_clamped_and_bounded():
    recs = [
        # 4 advances in 2 ms against a 10k fps roofline: util 0.2
        ExecRecord(instr=Run(member="a", slots=1, core="c"), slot=0,
                   seq=0, advances=4, t0=0.0, t1=0.002),
        # 50 advances in 1 ms = 50k fps achieved: clamps to 1.05
        ExecRecord(instr=Run(member="a", slots=1, core="c"), slot=1,
                   seq=1, advances=50, t0=0.002, t1=0.003),
        # member without pricing: no roofline args
        ExecRecord(instr=Run(member="b", slots=1, core="p"), slot=2,
                   seq=2, advances=1, t0=0.003, t1=0.004),
    ]
    doc = chrome_trace({"p0": recs}, roofline={"p0": {"a": 10_000.0}})
    runs = [e for e in doc["traceEvents"]
            if e["ph"] == "X" and e["cat"] == "RUN"]
    assert len(runs) == 3
    priced = [e for e in runs if "roofline_util" in e["args"]]
    assert len(priced) == 2
    for e in priced:
        assert 0 < e["args"]["roofline_util"] <= 1.05
        assert e["args"]["achieved_fps"] > 0
        assert e["args"]["roofline_fps"] == 10_000.0
    assert priced[0]["args"]["roofline_util"] == pytest.approx(0.2)
    assert priced[1]["args"]["roofline_util"] == 1.05
    assert "roofline_util" not in runs[2]["args"]


def test_chrome_trace_bubble_events():
    mk = lambda m, c, s, q: ExecRecord(  # noqa: E731
        instr=Run(member=m, slots=1, core=c), slot=s, seq=q,
        advances=1, t0=0.01 * s, t1=0.01 * s + 0.005)
    recs = [mk("a", "c", 0, 0), mk("b", "p", 0, 1),
            mk("a", "c", 1, 2), mk("a", "c", 2, 3),
            mk("b", "p", 3, 4), mk("a", "c", 3, 5)]
    doc = chrome_trace({"p0": recs})
    bubbles = [e for e in doc["traceEvents"]
               if e["ph"] == "X" and e["cat"] == "bubble"]
    # the p submesh is idle over slots 1-2 while c runs: one bubble,
    # labeled with the member that next RUNs on p
    assert len(bubbles) == 1
    b = bubbles[0]
    assert b["tid"] == 4
    assert b["name"] == "bubble p-submesh x2"
    assert b["args"] == {"core": "p", "slots": [1, 2],
                         "could_have_run": "b"}
    assert b["dur"] > 0
    # fully-busy streams produce no bubbles
    busy = [mk("a", "c", s, s) for s in range(3)] + \
           [mk("b", "p", s, 10 + s) for s in range(3)]
    doc2 = chrome_trace({"p0": busy})
    assert not [e for e in doc2["traceEvents"]
                if e.get("cat") == "bubble"]
