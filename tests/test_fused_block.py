"""Fused dw->pw block kernels, implicit-GEMM conv across the model zoo,
the graph fusion pass, and the block-shape autotuner cache."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.fusion import fused_layer_counts, plan_fusion
from repro.core.graph import LayerSpec
from repro.kernels import autotune
from repro.kernels.conv_gemm.ops import conv2d_gemm
from repro.kernels.conv_gemm.ref import conv2d_ref
from repro.kernels.fused_block.kernel import (fused_dw_pw_conv,
                                              fused_pw_dw_pw_conv)
from repro.kernels.fused_block.ops import fused_dw_pw
from repro.kernels.fused_block.ref import (fused_dw_pw_ref,
                                           fused_pw_dw_pw_ref)
from repro.models.zoo import get_graph

KEYS = jax.random.split(jax.random.PRNGKey(11), 8)


def rand(key, shape, scale=1.0, dtype=jnp.float32):
    return (jax.random.normal(key, shape) * scale).astype(dtype)


# --------------------------------------------------------------------------
# fused dw->pw vs the composed reference ops
# --------------------------------------------------------------------------
@pytest.mark.parametrize("h,w,c,co,s,bc,act", [
    (14, 14, 64, 128, 1, 32, "relu6"),
    (15, 13, 48, 56, 1, 32, "relu6"),     # odd H/W
    (28, 28, 100, 64, 2, 48, "relu6"),    # stride 2, C % block_c != 0
    (9, 9, 24, 40, 2, 64, "relu"),        # odd + stride 2 + bc > C
    (7, 7, 96, 32, 1, 8, None),
])
def test_fused_dw_pw_matches_composed(h, w, c, co, s, bc, act):
    x = rand(KEYS[0], (2, h, w, c), 0.5)
    dw_w = rand(KEYS[1], (3, 3, c), 0.3)
    dw_b = rand(KEYS[2], (c,), 0.1)
    pw_w = rand(KEYS[3], (c, co), 0.2)
    pw_b = rand(KEYS[4], (co,), 0.1)
    out = fused_dw_pw_conv(x, dw_w, dw_b, pw_w, pw_b, stride=s, pad=1,
                           dw_act="relu6", pw_act=act, block_c=bc,
                           block_n=64)
    ref = fused_dw_pw_ref(x, dw_w, dw_b, pw_w, pw_b, stride=s, pad=1,
                          dw_act="relu6", pw_act=act)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


def test_fused_dw_pw_no_bias():
    x = rand(KEYS[0], (1, 10, 10, 16), 0.5)
    dw_w = rand(KEYS[1], (3, 3, 16), 0.3)
    pw_w = rand(KEYS[2], (16, 24), 0.2)
    out = fused_dw_pw_conv(x, dw_w, None, pw_w, None, stride=1, pad=1)
    ref = fused_dw_pw_ref(x, dw_w, None, pw_w, None, stride=1, pad=1)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("h,ci,cm,co,s,res", [
    (14, 32, 96, 32, 1, True),            # residual add fused
    (14, 32, 96, 48, 1, False),
    (15, 24, 144, 32, 2, False),          # odd H + stride 2
])
def test_fused_inverted_residual_matches_composed(h, ci, cm, co, s, res):
    x = rand(KEYS[0], (1, h, h, ci), 0.5)
    ew = rand(KEYS[1], (ci, cm), 0.2)
    eb = rand(KEYS[2], (cm,), 0.1)
    dw_w = rand(KEYS[3], (3, 3, cm), 0.3)
    db = rand(KEYS[4], (cm,), 0.1)
    pw = rand(KEYS[5], (cm, co), 0.2)
    pb = rand(KEYS[6], (co,), 0.1)
    residual = x if res else None
    out = fused_pw_dw_pw_conv(x, ew, eb, dw_w, db, pw, pb, residual,
                              stride=s, pad=1, block_c=32, block_n=32)
    ref = fused_pw_dw_pw_ref(x, ew, eb, dw_w, db, pw, pb, residual,
                             stride=s, pad=1)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


def test_fused_ops_accept_4d_pointwise_weights():
    """models/cnn stores 1x1 weights as (1,1,Ci,Co); the ops reshape."""
    x = rand(KEYS[0], (1, 8, 8, 16), 0.5)
    dw_w = rand(KEYS[1], (3, 3, 16), 0.3)
    pw_w4 = rand(KEYS[2], (1, 1, 16, 24), 0.2)
    out = fused_dw_pw(x, dw_w, None, pw_w4, None, stride=1, pad=1)
    ref = fused_dw_pw_ref(x, dw_w, None, pw_w4.reshape(16, 24), None,
                          stride=1, pad=1)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


# --------------------------------------------------------------------------
# implicit-GEMM conv across every conv layer shape in the model zoo
# --------------------------------------------------------------------------
def _zoo_conv_sigs():
    seen, sigs = set(), []
    for name in ("mobilenet_v1", "mobilenet_v2", "squeezenet"):
        for l in get_graph(name).layers:
            if l.op not in ("conv", "fc"):
                continue
            sig = (l.H, l.W, l.C_i, l.C_o, l.K_h, l.K_w, l.stride, l.pad)
            if sig not in seen:
                seen.add(sig)
                sigs.append(sig)
    return sigs


@pytest.mark.parametrize("h,w,ci,co,kh,kw,s,p", _zoo_conv_sigs())
def test_implicit_gemm_zoo_layer(h, w, ci, co, kh, kw, s, p):
    """Acceptance: implicit-GEMM conv matches conv2d_ref to 1e-4 on every
    conv layer in the model zoo."""
    x = rand(KEYS[0], (1, h, w, ci), 0.5)
    wgt = rand(KEYS[1], (kh, kw, ci, co), 0.2)
    b = rand(KEYS[2], (co,), 0.1)
    out = conv2d_gemm(x, wgt, b, stride=s, pad=p, act="relu6")
    ref = conv2d_ref(x, wgt, b, stride=s, pad=p, act="relu6")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


def test_implicit_gemm_never_materializes_patch_matrix():
    """Acceptance: no (N*Ho*Wo, Kh*Kw*C) intermediate anywhere in the
    jaxpr of the conv path."""
    n, h, ci, co, k, s, p = 1, 28, 32, 64, 3, 1, 1
    ho = (h + 2 * p - k) // s + 1
    forbidden = {(n * ho * ho, k * k * ci)}

    x = jnp.zeros((n, h, h, ci))
    w = jnp.zeros((k, k, ci, co))
    jaxpr = jax.make_jaxpr(
        lambda a, b: conv2d_gemm(a, b, stride=s, pad=p))(x, w)

    def walk(jx):
        for eqn in jx.eqns:
            for v in eqn.outvars:
                shape = tuple(getattr(v.aval, "shape", ()))
                assert shape not in forbidden, (
                    f"HBM patch matrix {shape} materialized by "
                    f"{eqn.primitive}")
            for sub in eqn.params.values():
                if hasattr(sub, "eqns"):
                    walk(sub)
                elif hasattr(sub, "jaxpr") and hasattr(sub.jaxpr, "eqns"):
                    walk(sub.jaxpr)

    walk(jaxpr.jaxpr)


# --------------------------------------------------------------------------
# graph fusion pass
# --------------------------------------------------------------------------
def test_fusion_plan_zoo_counts():
    assert fused_layer_counts(get_graph("mobilenet_v1")) == {
        "single": 2, "dw_pw": 13}
    assert fused_layer_counts(get_graph("mobilenet_v2")) == {
        "single": 3, "dw_pw": 1, "pw_dw_pw": 16}
    # no dwconv anywhere -> nothing fuses
    assert fused_layer_counts(get_graph("squeezenet")) == {"single": 26}


def test_fusion_plan_covers_each_layer_once():
    for name in ("mobilenet_v1", "mobilenet_v2", "squeezenet"):
        g = get_graph(name)
        names = [n for grp in plan_fusion(g) for n in grp.layers]
        assert sorted(names) == sorted(l.name for l in g.layers)


def test_fusion_requires_linear_chain():
    """A dw whose output has two consumers must not fuse."""
    layers = [
        LayerSpec("dw", "dwconv", 8, 8, 16, 16, 3, 3, 1, pad=1),
        LayerSpec("pw_a", "conv", 8, 8, 16, 32, 1, 1, 1),
        LayerSpec("pw_b", "conv", 8, 8, 16, 32, 1, 1, 1),
    ]
    from repro.core.graph import LayerGraph
    g = LayerGraph("fanout", layers,
                   edges=[("dw", "pw_a"), ("dw", "pw_b")])
    assert all(grp.kind == "single" for grp in plan_fusion(g))


def test_fused_model_forward_matches_xla():
    """End-to-end: the fused Pallas plan reproduces the XLA forward."""
    from repro.models.cnn import build_model
    params, fwd, g = build_model("mobilenet_v2")
    x = rand(KEYS[0], (1, 224, 224, 3), 0.5)
    a = fwd(params, x)
    b = fwd(params, x, use_pallas=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=2e-3, atol=2e-3)


# --------------------------------------------------------------------------
# autotuner cache
# --------------------------------------------------------------------------
def test_autotune_cache_roundtrip_deterministic(tmp_path):
    path = str(tmp_path / "autotune.json")
    sig = autotune.LayerSig("conv", 8, 8, 8, 8, 3, 3, 1, 1)
    cfg = autotune.tune_layer(sig, path=path, reps=1)
    assert set(cfg) == {"block_h", "block_n"}
    # the JSON file round-trips to the same config
    with open(path) as f:
        raw = json.load(f)
    assert raw["version"] == autotune.CACHE_VERSION
    assert raw["entries"][sig.key()]["config"] == cfg
    autotune.clear_memory_cache()
    assert autotune.get_config(sig, path=path) == cfg
    # a second tune short-circuits on the cache: no benchmarking happens
    def boom(_cfg):
        raise AssertionError("re-benchmarked despite cache hit")
    assert autotune.tune(sig, boom, path=path) == cfg


def test_autotune_miss_falls_back_to_heuristic(tmp_path):
    path = str(tmp_path / "empty.json")
    sig = autotune.LayerSig("depthwise", 14, 14, 64, 64, 3, 3, 1, 1)
    assert autotune.get_config(sig, path=path) is None
    cfg = autotune.heuristic_config(sig)
    assert cfg["block_c"] >= 8


def test_autotune_key_distinguishes_shapes():
    a = autotune.LayerSig("conv", 14, 14, 32, 64, 3, 3, 1, 1)
    b = autotune.LayerSig("conv", 14, 14, 32, 64, 3, 3, 2, 1)
    assert a.key() != b.key()
