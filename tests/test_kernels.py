"""Per-kernel validation: shape/dtype sweeps + hypothesis property tests,
all against the pure-jnp oracles, in Pallas interpret mode (CPU)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.conv_gemm.kernel import matmul_bias_act
from repro.kernels.conv_gemm.ops import conv2d_gemm, pointwise_conv
from repro.kernels.conv_gemm.ref import conv2d_ref, matmul_bias_act_ref
from repro.kernels.depthwise.ops import depthwise
from repro.kernels.depthwise.ref import depthwise_conv2d_ref
from repro.kernels.attention.kernel import flash_attention
from repro.kernels.attention.ref import attention_ref
from repro.kernels.rmsnorm.kernel import rmsnorm
from repro.kernels.rmsnorm.ref import rmsnorm_ref


def tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=3e-4, atol=3e-4)


def rand(key, shape, dtype, scale=1.0):
    return (jax.random.normal(key, shape) * scale).astype(dtype)


KEYS = jax.random.split(jax.random.PRNGKey(42), 8)


# --------------------------------------------------------------------------
# conv_gemm (c-core analogue)
# --------------------------------------------------------------------------
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("m,k,n", [(8, 8, 8), (128, 128, 128),
                                   (100, 70, 30), (257, 129, 65),
                                   (1, 512, 1000)])
def test_matmul_shapes(m, k, n, dtype):
    x = rand(KEYS[0], (m, k), dtype, 0.3)
    w = rand(KEYS[1], (k, n), dtype, 0.3)
    b = rand(KEYS[2], (n,), dtype)
    out = matmul_bias_act(x, w, b, act="relu")
    ref = matmul_bias_act_ref(x, w, b, act="relu")
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **tol(dtype))


@pytest.mark.slow
@settings(max_examples=20, deadline=None)
@given(st.integers(1, 64), st.integers(1, 64), st.integers(1, 64),
       st.sampled_from([None, "relu", "relu6"]))
def test_matmul_property(m, k, n, act):
    x = rand(KEYS[0], (m, k), jnp.float32, 0.3)
    w = rand(KEYS[1], (k, n), jnp.float32, 0.3)
    out = matmul_bias_act(x, w, None, act=act, block=(32, 32, 32))
    ref = matmul_bias_act_ref(x, w, None, act=act)
    np.testing.assert_allclose(out, ref, rtol=3e-4, atol=3e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("h,ci,co,k,s,pad", [
    (14, 32, 64, 3, 1, 1), (28, 16, 24, 3, 2, 1),
    (8, 8, 16, 1, 1, 0), (224 // 8, 3, 32, 3, 2, 1)])
def test_conv2d_gemm(h, ci, co, k, s, pad, dtype):
    x = rand(KEYS[0], (2, h, h, ci), dtype, 0.5)
    w = rand(KEYS[1], (k, k, ci, co), dtype, 0.2)
    b = rand(KEYS[2], (co,), dtype)
    out = conv2d_gemm(x, w, b, stride=s, pad=pad, act="relu6")
    ref = conv2d_ref(x, w, b, stride=s, pad=pad, act="relu6")
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **tol(dtype))


def test_pointwise_matches_conv():
    x = rand(KEYS[0], (2, 7, 7, 64), jnp.float32, 0.5)
    w = rand(KEYS[1], (1, 1, 64, 32), jnp.float32, 0.2)
    np.testing.assert_allclose(pointwise_conv(x, w),
                               conv2d_ref(x, w, stride=1, pad=0),
                               rtol=3e-4, atol=3e-4)


# --------------------------------------------------------------------------
# depthwise (p-core analogue)
# --------------------------------------------------------------------------
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("h,c,s", [(14, 512, 1), (28, 256, 2), (7, 1024, 1),
                                   (9, 24, 2), (112, 32, 1)])
def test_depthwise_shapes(h, c, s, dtype):
    x = rand(KEYS[0], (2, h, h, c), dtype, 0.5)
    w = rand(KEYS[1], (3, 3, c), dtype, 0.3)
    b = rand(KEYS[2], (c,), dtype)
    out = depthwise(x, w, b, stride=s, pad=1, act="relu6")
    ref = depthwise_conv2d_ref(x, w, b, stride=s, pad=1, act="relu6")
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **tol(dtype))


@settings(max_examples=15, deadline=None)
@given(st.integers(4, 32), st.sampled_from([8, 16, 56]),
       st.sampled_from([1, 2]), st.sampled_from([3, 5]))
def test_depthwise_property(h, c, s, k):
    x = rand(KEYS[0], (1, h, h, c), jnp.float32, 0.5)
    w = rand(KEYS[1], (k, k, c), jnp.float32, 0.3)
    pad = k // 2
    out = depthwise(x, w, None, stride=s, pad=pad)
    ref = depthwise_conv2d_ref(x, w, None, stride=s, pad=pad)
    np.testing.assert_allclose(out, ref, rtol=3e-4, atol=3e-4)


# --------------------------------------------------------------------------
# flash attention
# --------------------------------------------------------------------------
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,hq,hkv,sq,sk,d,causal", [
    (2, 8, 2, 64, 64, 32, True),      # GQA
    (1, 4, 4, 128, 128, 64, True),    # MHA
    (2, 6, 1, 1, 256, 64, False),     # MQA decode shape
    (1, 14, 2, 37, 37, 64, True),     # qwen2-0.5b heads (non-pow2)
    (1, 2, 2, 8, 200, 128, False),    # cross-attn shape (sq != sk)
])
def test_flash_attention(b, hq, hkv, sq, sk, d, causal, dtype):
    q = rand(KEYS[0], (b, hq, sq, d), dtype, 0.5)
    k = rand(KEYS[1], (b, hkv, sk, d), dtype, 0.5)
    v = rand(KEYS[2], (b, hkv, sk, d), dtype, 0.5)
    out = flash_attention(q, k, v, causal=causal, block_q=32, block_k=32)
    ref = attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               **(dict(rtol=3e-2, atol=3e-2)
                                  if dtype == jnp.bfloat16
                                  else dict(rtol=2e-4, atol=2e-4)))


@pytest.mark.slow
@settings(max_examples=10, deadline=None)
@given(st.integers(1, 3), st.sampled_from([(4, 2), (8, 1), (6, 6)]),
       st.integers(1, 80), st.sampled_from([32, 64]))
def test_flash_attention_property(b, heads, sq, d):
    hq, hkv = heads
    q = rand(KEYS[0], (b, hq, sq, d), jnp.float32, 0.5)
    k = rand(KEYS[1], (b, hkv, sq, d), jnp.float32, 0.5)
    v = rand(KEYS[2], (b, hkv, sq, d), jnp.float32, 0.5)
    out = flash_attention(q, k, v, causal=True, block_q=16, block_k=16)
    ref = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(out, ref, rtol=3e-4, atol=3e-4)


def test_flash_attention_softmax_rows_sum():
    """Property: attention output of constant-V equals that constant."""
    b, hq, hkv, s, d = 1, 4, 2, 64, 32
    q = rand(KEYS[0], (b, hq, s, d), jnp.float32)
    k = rand(KEYS[1], (b, hkv, s, d), jnp.float32)
    v = jnp.ones((b, hkv, s, d), jnp.float32) * 3.5
    out = flash_attention(q, k, v, causal=True, block_q=16, block_k=16)
    np.testing.assert_allclose(out, jnp.full_like(out, 3.5), rtol=1e-5)


# --------------------------------------------------------------------------
# rmsnorm
# --------------------------------------------------------------------------
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [(4, 256), (2, 16, 896), (1, 1, 12288),
                                   (3, 7, 1024)])
def test_rmsnorm(shape, dtype):
    x = rand(KEYS[0], shape, dtype, 2.0)
    w = rand(KEYS[1], shape[-1:], dtype)
    out = rmsnorm(x, w)
    ref = rmsnorm_ref(x, w)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **tol(dtype))


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 100), st.sampled_from([64, 896, 1536]))
def test_rmsnorm_property(rows, d):
    x = rand(KEYS[0], (rows, d), jnp.float32, 2.0)
    w = jnp.ones((d,), jnp.float32)
    out = rmsnorm(x, w)
    # unit weight: per-row RMS of output ~= 1
    rms = np.sqrt(np.mean(np.asarray(out) ** 2, axis=-1))
    np.testing.assert_allclose(rms, np.ones_like(rms), rtol=1e-3)
