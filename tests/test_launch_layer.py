"""Launch-layer units: sharding rules, sanitize fallbacks, policies,
HLO analysis (trip attribution / dot flops / collectives), roofline model.

These run on the 1-device CPU test process: meshes here are 1x1 (sanitize
drops everything not divisible by 1 — exercised via explicit fake-mesh
shims below), and the HLO parser is tested on synthetic HLO text.
"""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.launch.hlo_analysis import HloIndex, analyze_hlo
from repro.launch.roofline_model import hbm_bytes_per_device
from repro.launch.sharding import (ShardingPolicy, _apply_policy,
                                   _param_rule, auto_policy, sanitize,
                                   zero1_specs)
from repro.configs.registry import get_arch


class FakeMesh:
    """Duck-typed mesh: sanitize/_axsize only touch shape/axis_names."""

    def __init__(self, **axes):
        self.shape = dict(axes)
        self.axis_names = tuple(axes)


MESH = FakeMesh(data=16, model=16)


# --------------------------------------------------------------------------
# sanitize
# --------------------------------------------------------------------------
def test_sanitize_keeps_divisible():
    assert sanitize((256, 4096), P("data", None), MESH) == P("data", None)
    assert sanitize((12288, 12288), P("data", "model"), MESH) \
        == P("data", "model")


def test_sanitize_drops_nondivisible():
    fb = []
    # 14 heads on a 16-way axis (qwen2-0.5b case)
    assert sanitize((14, 64), P("model", None), MESH, fb) == P(None, None)
    assert fb


def test_sanitize_tuple_degrades_to_member():
    fb = []
    # 29568 % 256 != 0 but % 16 == 0 (qwen2-vl d_ff under feature_2d)
    out = sanitize((29568,), P(("data", "model")), MESH, fb)
    assert out in (P("data"), P("model"))
    assert fb


def test_sanitize_missing_axis():
    m = FakeMesh(data=16)   # no 'model'
    assert sanitize((64,), P("model"), m) == P(None)


# --------------------------------------------------------------------------
# param rules + policies
# --------------------------------------------------------------------------
def test_param_rules_canonical():
    assert _param_rule("blocks/attn/wq", 3) == P(None, "data", "model")
    assert _param_rule("blocks/attn/wo", 3) == P(None, "model", "data")
    assert _param_rule("blocks/mlp/wd", 3) == P(None, "model", "data")
    assert _param_rule("embed", 2) == P(None, "model")
    assert _param_rule("lm_head", 2) == P("data", "model")
    assert _param_rule("blocks/ln1", 2) == P()


def test_policy_no_fsdp_drops_data():
    spec = _apply_policy(P(None, "data", "model"),
                         ShardingPolicy(fsdp=False))
    assert spec == P(None, None, "model")


def test_policy_dp_only_replicates():
    spec = _apply_policy(P(None, "data", "model"),
                         ShardingPolicy(dp_only=True))
    assert spec == P(None, None, None)


def test_policy_feature_2d():
    spec = _apply_policy(P(None, "data", "model"),
                         ShardingPolicy(feature_2d=True))
    assert spec == P(None, "data", ("data", "model"))


def test_auto_policy_thresholds():
    # 0.5B trains without FSDP; 104B needs it
    assert auto_policy(int(0.5e9), "train").fsdp is False
    assert auto_policy(int(104e9), "train").fsdp is True
    # serving a 72B wants 2D features; a 0.5B does not
    assert auto_policy(int(72e9), "decode").feature_2d is True
    assert auto_policy(int(0.5e9), "decode").feature_2d is False


def test_zero1_specs_shard_largest_dim():
    mesh = FakeMesh(data=16, model=16)
    tree = {"w": jax.ShapeDtypeStruct((24, 896, 1152), jnp.float32),
            "b": jax.ShapeDtypeStruct((7,), jnp.float32)}
    specs = zero1_specs(tree, mesh)
    assert "model" in tuple(specs["w"])
    assert specs["b"] == P()


# --------------------------------------------------------------------------
# HLO analysis
# --------------------------------------------------------------------------
SYNTH_HLO = """
HloModule test
ENTRY %main (p0: f32[8,16]) -> f32[8,16] {
  %w1 = (s32[], f32[8,16]) while(%t), condition=%cond, body=%body, metadata={op_name="jit(f)/while"}, backend_config={"known_trip_count":{"n":"24"}}
}
%body (p: (s32[], f32[8,16])) -> (s32[], f32[8,16]) {
  %a = f32[8,32]{1,0} parameter(0)
  %b = f32[32,16]{1,0} parameter(1)
  %dot.1 = f32[8,16]{1,0} dot(%a, %b), lhs_contracting_dims={1}, rhs_contracting_dims={0}, metadata={op_name="jit(f)/while/body/dot_general"}
  %ar = f32[8,16]{1,0} all-reduce(%dot.1), replica_groups={}, metadata={op_name="jit(f)/while/body/ar"}
}
"""


def test_hlo_dot_flops_and_trip_attribution():
    s = analyze_hlo(SYNTH_HLO)
    # dot: 2*8*16*32 = 8192 flops, x24 trips
    assert s["flops_per_device"] == pytest.approx(8192 * 24)
    # all-reduce: 8*16*4 bytes x24
    assert s["collective_bytes_per_device"] == pytest.approx(512 * 24)
    assert s["collective_counts"]["all-reduce"] == 24


def test_hlo_duplicate_while_opnames_deduped():
    dup = SYNTH_HLO + SYNTH_HLO.replace("%w1", "%w2").replace(
        "ENTRY ", "")
    idx = HloIndex(dup)
    # two while instructions, one op_name -> one multiplier entry
    assert idx.multiplier("jit(f)/while/body/dot_general") == 24


def test_hlo_nested_whiles_multiply():
    nested = SYNTH_HLO.replace(
        'op_name="jit(f)/while/body/dot_general"',
        'op_name="jit(f)/while/body/inner/while/body/dot_general"')
    nested = nested.replace(
        '%ar = f32[8,16]{1,0} all-reduce(%dot.1), replica_groups={}, '
        'metadata={op_name="jit(f)/while/body/ar"}',
        '%w3 = (s32[]) while(%q), condition=%c2, body=%b2, '
        'metadata={op_name="jit(f)/while/body/inner/while"}, '
        'backend_config={"known_trip_count":{"n":"4"}}')
    s = analyze_hlo(nested)
    assert s["flops_per_device"] == pytest.approx(8192 * 24 * 4)


def test_hlo_ignores_non_loop_ops():
    flat = """%dot.9 = bf16[4,4]{1,0} dot(%x, %y), lhs_contracting_dims={1}
%x = bf16[4,8]{1,0} parameter(0)
"""
    s = analyze_hlo(flat)
    assert s["flops_per_device"] == pytest.approx(2 * 4 * 4 * 8)


# --------------------------------------------------------------------------
# roofline memory model
# --------------------------------------------------------------------------
def test_roofline_memory_decode_dominated_by_kv():
    cfg = get_arch("command_r_plus_104b")
    dec = hbm_bytes_per_device(cfg, "decode", 32768, 128, 256)
    w_only = 2.0 * cfg.param_count() / 256
    assert dec > 3 * w_only        # KV read >> weight read at 32k x 128


def test_roofline_memory_train_scales_with_microbatches():
    cfg = get_arch("qwen2_5_14b")
    a = hbm_bytes_per_device(cfg, "train", 4096, 256, 256, microbatches=4)
    b = hbm_bytes_per_device(cfg, "train", 4096, 256, 256, microbatches=8)
    assert b > a                   # more weight streams


def test_roofline_memory_ssm_state():
    cfg = get_arch("xlstm_350m")
    d = hbm_bytes_per_device(cfg, "long-decode", 524288, 1, 256)
    assert d > 0
    # recurrent state is O(1) in seq: same bytes for 32k and 500k
    d2 = hbm_bytes_per_device(cfg, "decode", 32768, 1, 256)
    assert d == pytest.approx(d2)
