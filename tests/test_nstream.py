"""N-stream continuous-batching scheduler: the ISSUE-1 generalization of
the paper's two-image interleave.  Covers the flow-shop makespan (N=2
reduction to the seed's closed form), load-balance monotonicity at every
N, makespan-aware admission, and runtime-vs-model token accounting."""
import jax
import pytest

from repro.configs.registry import get_arch, get_smoke
from repro.dualmesh import (DualMeshRunner, TpuModel, best_schedule, build,
                            load_balance, plan_admission, request_stages,
                            search, split_mesh, wave_makespan)
from repro.dualmesh.partition import abstract_split

CFG = get_arch("qwen2_5_14b")
HW = TpuModel()
DUAL = abstract_split(256, 0.5)


def _sched(n_streams, scheme="stage_type"):
    stages = request_stages(CFG, [(8, 4096, 64)])
    return build(stages, CFG, DUAL, HW, scheme, n_streams=n_streams)


# --------------------------------------------------------------------------
# Makespan simulation
# --------------------------------------------------------------------------
def _closed_form(t):
    """The seed's corrected T_b2 two-stream closed form."""
    return t[0] + sum(max(t[i], t[i - 1])
                      for i in range(1, len(t))) + t[-1]


def test_nstream_makespan_reduces_to_two_stream_recurrence():
    """The seed's corrected T_b2 closed form is exactly the N=2 case of
    the FIFO simulation — including multi-request chains with many
    alternating groups, where a naive flow-shop recurrence would
    double-book a submesh and under-report."""
    s2 = _sched(2)
    assert s2.makespan() == pytest.approx(_closed_form(s2.latencies()),
                                          rel=1e-12)
    # 4-request chain -> 8 alternating groups
    stages = request_stages(CFG, [(8, 8192, 256)] * 4)
    for scheme in ("stage_type", "round_robin"):
        s = build(stages, CFG, DUAL, HW, scheme, n_streams=2)
        assert len(s.groups) > 2
        assert s.makespan() == pytest.approx(_closed_form(s.latencies()),
                                             rel=1e-12)


def test_two_stream_equivalence_on_random_chains():
    """N=2 simulation == closed form for arbitrary latency chains."""
    import random
    from repro.dualmesh.schedule import DualSchedule, MeshGroup

    rng = random.Random(0)
    for _ in range(200):
        g = rng.randint(1, 9)
        lat = [rng.choice([1, 2, 3, 5, 8, 100]) * rng.random()
               for _ in range(g)]
        sched = DualSchedule(
            [MeshGroup("c" if i % 2 == 0 else "p", []) for i in range(g)],
            CFG, DUAL, HW, n_streams=2)
        sched.latencies = lambda lat=lat: lat      # inject raw chain
        assert sched.makespan() == pytest.approx(_closed_form(lat),
                                                 rel=1e-9)


def test_single_stream_makespan_is_chain_sum():
    s = _sched(1)
    assert s.makespan() == pytest.approx(sum(s.latencies()))


def test_makespan_monotone_and_amortizing_in_n():
    """More streams: longer makespan, but shorter per-stream share (the
    stagger amortizes the pipeline fill/drain) — so throughput rises."""
    s = _sched(2)
    spans = [s.makespan(n) for n in (1, 2, 4, 8, 16)]
    assert all(b > a for a, b in zip(spans, spans[1:]))
    per_stream = [sp / n for sp, n in zip(spans, (1, 2, 4, 8, 16))]
    assert all(b <= a + 1e-12 for a, b in zip(per_stream, per_stream[1:]))
    thr = [s.throughput_tokens_per_s(n) for n in (1, 2, 4, 8, 16)]
    assert all(b > a for a, b in zip(thr, thr[1:]))


@pytest.mark.parametrize("n", [2, 4, 8])
def test_load_balance_never_worse_at_any_n(n):
    for scheme in ("stage_type", "greedy", "round_robin"):
        s = _sched(n, scheme)
        lb = load_balance(s)
        assert lb.n_streams == n
        assert lb.makespan() <= s.makespan() + 1e-12


def test_best_schedule_throughput_nondecreasing_in_n():
    stages = request_stages(CFG, [(8, 4096, 64)])
    thr = [best_schedule(stages, CFG, DUAL, HW,
                         n_streams=n).throughput_tokens_per_s()
           for n in (2, 4, 8, 16)]
    assert all(b >= a for a, b in zip(thr, thr[1:]))


# --------------------------------------------------------------------------
# Token accounting (no hardcoded two-stream factor)
# --------------------------------------------------------------------------
def test_token_accounting_is_batch_and_n_aware():
    s = _sched(4)
    per_stream = 8 * 4096 + 8 * 64          # batch*(prompt + gen)
    assert s.stream_tokens() == per_stream
    assert s.total_tokens() == 4 * per_stream
    assert s.total_tokens(16) == 16 * per_stream


def test_runtime_edge_requests():
    """gen_steps=0 is prefill-only (no phantom emit); quantum=0 is
    clamped rather than spinning forever."""
    scfg = get_smoke("qwen2_0_5b")
    from repro.lm.model import init_params
    params = init_params(scfg, jax.random.PRNGKey(0))
    dual = split_mesh(jax.devices(), 0.5)
    r = DualMeshRunner(scfg, params, dual, max_len=32)
    p = jax.random.randint(jax.random.PRNGKey(1), (1, 4), 0, scfg.vocab)
    res = r.serve([p, p], gen_steps=[0, 2], quantum=0)
    assert res.outputs[0].shape == (1, 4)       # prompt unchanged
    assert res.outputs[1].shape == (1, 6)       # prompt + 2 generated
    assert res.stats["decode_tokens"] == 2


def test_runtime_tokens_match_schedule_accounting():
    """The model's throughput numerator equals the tokens the runtime
    actually processes/emits for the same (N x (batch, prompt, gen))
    workload."""
    scfg = get_smoke("qwen2_0_5b")
    from repro.lm.model import init_params
    params = init_params(scfg, jax.random.PRNGKey(0))
    dual = split_mesh(jax.devices(), 0.5)
    r = DualMeshRunner(scfg, params, dual, max_len=32)
    n, batch, plen, gen = 3, 2, 8, 4
    prompts = [jax.random.randint(k, (batch, plen), 0, scfg.vocab)
               for k in jax.random.split(jax.random.PRNGKey(1), n)]
    res = r.serve(prompts, gen_steps=gen)
    sched = build(request_stages(scfg, [(batch, plen, gen)]), scfg, DUAL,
                  HW, "stage_type", n_streams=n)
    assert res.stats["total_tokens"] == sched.total_tokens()
    assert res.stats["prefill_tokens"] == n * batch * plen
    assert res.stats["decode_tokens"] == n * batch * gen


# --------------------------------------------------------------------------
# Makespan-aware admission
# --------------------------------------------------------------------------
def test_admission_plan_beats_or_matches_extremes():
    plan = plan_admission(CFG, DUAL, HW, 8, 4096, 256, 8)
    assert 1 <= plan.group_size <= 8
    for g in (1, 8):
        assert plan.est_makespan <= wave_makespan(
            CFG, DUAL, HW, 8, 4096, 256, 8, g) + 1e-12


def test_admission_respects_max_group():
    plan = plan_admission(CFG, DUAL, HW, 8, 4096, 256, 16, max_group=2)
    assert plan.group_size <= 2


# --------------------------------------------------------------------------
# Search threading
# --------------------------------------------------------------------------
def test_search_carries_n_streams():
    stages = request_stages(CFG, [(8, 4096, 64)])
    res = search(stages, CFG, n_devices=256, max_evals=4, n_streams=8)
    assert res.n_streams == 8
    assert res.schedule.n_streams == 8
    assert res.makespan == pytest.approx(res.schedule.makespan())


def test_search_still_explores_theta():
    """The branch-and-bound must keep visiting thetas beyond the 0.5
    seed — an inadmissible (over-scaled) bound would prune everything."""
    stages = request_stages(CFG, [(8, 1024, 1024)] * 2)
    res = search(stages, CFG, n_devices=256, max_evals=8)
    assert len(res.visited) > 1
