"""Unified telemetry (ISSUE-10): the zero-dependency metrics registry,
Prometheus/JSON exposition, the slot/wall domain contract — slot-domain
snapshots are a pure function of the instruction stream, so a replay
reproduces them dict-equal, including under crash recovery — wire-v2
telemetry shipping from real worker processes, and the serve CLI's
--metrics flags."""
import io
import json
import os
import sys

import pytest

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

from test_fleet import _stub_fleet  # noqa: E402

from repro.fleet import (Fault, FaultInjector, FaultPlan,  # noqa: E402
                         MultiPoolRouter, WeightedFair, stream_from_json,
                         stream_signature, stream_to_json)
from repro.fleet.net import wire  # noqa: E402
from repro.obs import (Registry, parse_label_key, to_json,  # noqa: E402
                       to_prometheus, write_metrics)
from repro.serving import QueueFull, Request, poisson_arrivals  # noqa: E402


# --------------------------------------------------------------------------
# registry primitives
# --------------------------------------------------------------------------
def test_counter_gauge_histogram_basics():
    reg = Registry()
    c = reg.counter("reqs_total", "requests", "slot")
    c.inc(labels={"pool": "p0"})
    c.inc(2, labels={"pool": "p0"})
    c.inc(labels={"pool": "p1"})
    assert c.series == {"pool=p0": 3, "pool=p1": 1}
    g = reg.gauge("depth", "queue depth", "slot")
    g.set(5)
    g.set(2)
    assert g.series == {"": 2}                   # last write wins
    h = reg.histogram("lat", "latency", bounds=(0.1, 1.0))
    for v in (0.05, 0.5, 0.5, 7.0):
        h.observe(v)
    s = h.series[""]
    assert s["counts"] == [1, 2, 1] and s["n"] == 4
    assert s["sum"] == pytest.approx(8.05)
    # same name must come back as the same metric
    assert reg.counter("reqs_total") is c
    with pytest.raises(ValueError, match="re-registered"):
        reg.gauge("reqs_total")
    with pytest.raises(ValueError, match="re-registered"):
        reg.counter("reqs_total", domain="wall")
    with pytest.raises(ValueError, match="unknown metric domain"):
        reg.counter("x", domain="lunar")
    with pytest.raises(ValueError, match="strictly"):
        reg.histogram("bad", bounds=(1.0, 1.0))


def test_label_canonicalization_and_limits():
    reg = Registry()
    c = reg.counter("c")
    c.inc(labels={"b": "2", "a": "1"})
    c.inc(labels={"a": "1", "b": "2"})            # same set, any order
    assert c.series == {"a=1,b=2": 2}
    assert parse_label_key("a=1,b=2") == {"a": "1", "b": "2"}
    assert parse_label_key("") == {}
    with pytest.raises(ValueError, match="may not contain"):
        c.inc(labels={"a": "x,y"})
    with pytest.raises(ValueError, match="may not contain"):
        c.inc(labels={"a": "x=y"})


def test_disabled_registry_noops_and_zero_inc_creates_no_series():
    reg = Registry(enabled=False)
    reg.counter("c").inc(5)
    reg.gauge("g").set(1)
    reg.histogram("h").observe(0.5)
    snap = reg.snapshot()
    assert all(not e["series"] for part in snap.values()
               for e in part.values())
    live = Registry()
    live.counter("c").inc(0, labels={"pool": "p0"})
    assert live.counter("c").series == {}        # no zero-valued series


def test_snapshot_is_deterministic_and_json_safe():
    def build():
        reg = Registry()
        reg.counter("b_total", "b", "slot").inc(labels={"z": "1"})
        reg.counter("a_total", "a", "wall").inc(2)
        reg.gauge("g").set(3, labels={"pool": "p1"})
        reg.histogram("h", bounds=(1.0,)).observe(0.5)
        return reg
    s1, s2 = build().snapshot(), build().snapshot()
    assert s1 == s2
    assert json.loads(json.dumps(s1)) == s1
    assert list(s1["counters"]) == ["a_total", "b_total"]
    slot_only = build().snapshot(domain="slot")
    assert list(slot_only["counters"]) == ["b_total"]
    assert not slot_only["histograms"]           # h defaults to wall


def test_absorb_replaces_per_source_and_merges():
    worker = Registry()
    worker.counter("n_total", "n", "slot").inc(3, labels={"pool": "w0"})
    worker.histogram("h", "h", bounds=(1.0,)).observe(0.5)
    coord = Registry()
    coord.counter("n_total", "n", "slot").inc(labels={"pool": "co"})
    coord.absorb(worker.snapshot(), source="w0")
    merged = coord.snapshot()
    assert merged["counters"]["n_total"]["series"] == {
        "pool=co": 1, "pool=w0": 3}
    assert merged["histograms"]["h"]["series"][""]["n"] == 1
    # a later cumulative snapshot REPLACES the source's contribution —
    # never double-counts
    worker.counter("n_total").inc(2, labels={"pool": "w0"})
    coord.absorb(worker.snapshot(), source="w0")
    assert coord.snapshot()["counters"]["n_total"]["series"] == {
        "pool=co": 1, "pool=w0": 5}
    assert coord.sources == ["w0"]
    assert coord.snapshot(sources=False)["counters"]["n_total"][
        "series"] == {"pool=co": 1}


# --------------------------------------------------------------------------
# exposition
# --------------------------------------------------------------------------
def _sample_registry():
    reg = Registry()
    reg.counter("reqs_total", "requests served", "slot").inc(
        3, labels={"pool": "p0", "model": "mbv1"})
    reg.gauge("depth", "queue depth", "wall").set(2.5)
    h = reg.histogram("lat_seconds", "latency", bounds=(0.1, 1.0))
    for v in (0.05, 0.5, 3.0):
        h.observe(v)
    return reg


def test_prometheus_exposition_format():
    text = to_prometheus(_sample_registry().snapshot())
    assert '# HELP reqs_total requests served [domain=slot]' in text
    assert '# TYPE reqs_total counter' in text
    assert 'reqs_total{model="mbv1",pool="p0"} 3' in text
    assert 'depth 2.5' in text
    # histogram buckets are cumulative with a closing +Inf
    assert 'lat_seconds_bucket{le="0.1"} 1' in text
    assert 'lat_seconds_bucket{le="1"} 2' in text
    assert 'lat_seconds_bucket{le="+Inf"} 3' in text
    assert 'lat_seconds_count 3' in text
    assert 'lat_seconds_sum 3.55' in text


def test_json_exposition_and_write_metrics(tmp_path, capsys):
    reg = _sample_registry()
    assert json.loads(to_json(reg.snapshot())) == reg.snapshot()
    p_json = tmp_path / "m.json"
    assert write_metrics(reg, str(p_json)) == "json"
    assert json.loads(p_json.read_text()) == reg.snapshot()
    p_prom = tmp_path / "m.prom"
    assert write_metrics(reg, str(p_prom)) == "prom"
    assert p_prom.read_text() == to_prometheus(reg.snapshot())
    assert write_metrics(reg, "-") == "prom"
    assert "reqs_total" in capsys.readouterr().out


# --------------------------------------------------------------------------
# the determinism contract: slot-domain metrics replay dict-equal
# --------------------------------------------------------------------------
def _mk_router(injector=None):
    def pool():
        return _stub_fleet(cores=("c", "p"), names=["a", "b"],
                           policy=WeightedFair(), service_steps=2,
                           max_queue=16)
    return MultiPoolRouter({"p0": pool(), "p1": pool()},
                           injector=injector)


def _drive(router, reqs, arrivals, migrate_at=3):
    order = sorted(range(len(reqs)), key=lambda i: arrivals[i])
    nxt, step, refused = 0, 0, []
    while nxt < len(order) or refused or router.has_work:
        due, refused = refused, []
        while nxt < len(order) and arrivals[order[nxt]] <= step:
            due.append(order[nxt])
            nxt += 1
        for i in due:
            try:
                router.submit(reqs[i])
            except QueueFull:
                refused.append(i)
        if (step == migrate_at and not router.dead
                and router.executors["p1"].fleet.queued):
            router.migrate("p1", "p0")
        if router.has_work:
            router.step()
        step += 1


def _replayed(live, reqs):
    rt = {name: stream_from_json(stream_to_json(recs, pool=name))
          for name, recs in live.streams().items()}
    fresh = _mk_router()
    fresh.replay(rt, live.placements, reqs, events=live.events)
    assert stream_signature(fresh.stream()) == \
        stream_signature(live.stream())
    return fresh


@pytest.mark.parametrize("seed", [None, 3, 11])
def test_slot_metrics_replay_dict_equal(seed):
    """The ISSUE-10 acceptance property: the slot-domain registry
    snapshot of a 2-pool live run — clean, or crash-recovering under a
    seeded fault plan — equals its replay's snapshot exactly.  Wall
    metrics exist on the live side only and stay out of the compare."""
    n = 12
    arrivals = poisson_arrivals(n, rate=2.0, seed=seed or 0)

    def reqs():
        return [Request(i, model="ab"[i % 2]) for i in range(n)]

    injector = None
    if seed is not None:
        plan = FaultPlan.generate(seed, pools=["p0", "p1"],
                                  members=["a", "b"], n=3, max_slot=6)
        injector = FaultInjector(plan)
    live = _mk_router(injector=injector)
    _drive(live, reqs(), arrivals)
    fresh = _replayed(live, reqs())

    live_slot = live.obs.snapshot(domain="slot")
    replay_slot = fresh.obs.snapshot(domain="slot")
    assert live_slot == replay_slot
    # the compare is not vacuous: executed instructions were counted
    assert live_slot["counters"]["fleet_instructions_total"]["series"]
    assert live_slot["counters"]["router_placements_total"]["series"]
    if seed is not None and live.events:
        assert live_slot["counters"][
            "router_recovery_events_total"]["series"]
    # wall-domain values exist live (durations were observed) but are
    # confined to their own channel
    assert live.obs.snapshot(domain="wall")["histograms"][
        "fleet_instr_seconds"]["series"]


def test_fault_crash_recovery_metrics_replay_dict_equal():
    """Pin the crash path specifically: a pool_crash fault produces
    recovery events and retired-status churn, and the slot snapshot
    still replays dict-equal."""
    plan = FaultPlan(faults=(Fault(kind="pool_crash", pool="p0",
                                   slot=2),))
    live = _mk_router(injector=FaultInjector(plan))
    reqs = [Request(i, model="ab"[i % 2]) for i in range(8)]
    for r in reqs:
        live.submit(r)
    live.drain()
    assert list(live.dead) == ["p0"]
    fresh = _replayed(live, [Request(i, model="ab"[i % 2])
                             for i in range(8)])
    assert live.obs.snapshot(domain="slot") == \
        fresh.obs.snapshot(domain="slot")
    kinds = live.obs.snapshot(domain="slot")["counters"][
        "router_recovery_events_total"]["series"]
    assert "kind=fail" in kinds and "kind=recover" in kinds


def test_registry_not_shared_across_runs():
    """Live and replay routers in one process own separate registries —
    the one-registry-per-engine rule that keeps snapshots comparable."""
    a, b = _mk_router(), _mk_router()
    assert a.obs is not b.obs
    for ex in a.executors.values():
        assert ex.obs is a.obs


# --------------------------------------------------------------------------
# wire v2: telemetry envelopes + version compat
# --------------------------------------------------------------------------
def test_wire_v2_telemetry_round_trip():
    snap = _sample_registry().snapshot()
    doc = wire.unpack_env(wire.pack_env(
        {"kind": "telemetry_snap", "snapshot": snap})[4:])
    assert doc["v"] == wire.WIRE_VERSION == 2
    assert doc["snapshot"] == snap
    assert wire.unpack_env(wire.pack_env({"kind": "telemetry"})[4:])[
        "kind"] == "telemetry"


def test_wire_v1_still_readable_but_not_with_v2_kinds():
    body = json.dumps({"v": 1, "kind": "ping"}).encode()
    assert wire.unpack_env(body)["kind"] == "ping"
    drift = json.dumps({"v": 1, "kind": "telemetry"}).encode()
    with pytest.raises(wire.WireError, match="v2-only kind"):
        wire.unpack_env(drift)
    with pytest.raises(wire.WireError, match="not in"):
        wire.unpack_env(json.dumps({"v": 3, "kind": "ping"}).encode())


def test_channel_counts_envelopes_when_instrumented():
    class _Sock:
        def __init__(self):
            self.buf = io.BytesIO()

        def settimeout(self, t):
            pass

        def makefile(self, mode):
            return self.buf

    chan = wire.Channel(_Sock())
    chan.obs = Registry()
    chan.send({"kind": "ping"})
    chan._f.seek(0)
    assert chan.recv()["kind"] == "ping"
    snap = chan.obs.snapshot(domain="wall")
    env = snap["counters"]["net_envelopes_total"]["series"]
    assert env == {"dir=in,kind=ping": 1, "dir=out,kind=ping": 1}
    assert snap["counters"]["net_bytes_total"]["series"][
        "dir=out"] > 4


# --------------------------------------------------------------------------
# real worker processes: telemetry collection across the socket
# --------------------------------------------------------------------------
def test_socket_workers_ship_telemetry_and_sigkill_bounds_loss():
    """Workers answer the wire-v2 ``telemetry`` RPC with a cumulative
    snapshot the coordinator absorbs per source; killing a worker loses
    at most the window since its last collect — everything already
    shipped stays in the coordinator registry."""
    from repro.fleet.net.coordinator import (connect, start_workers,
                                             stop_workers)

    spec = "cnn:c:2,lm:p:3:opaque"
    env = {**os.environ,
           "PYTHONPATH": os.pathsep.join(
               [os.path.join(os.path.dirname(os.path.dirname(
                   os.path.abspath(__file__))), "src"),
                os.environ.get("PYTHONPATH", "")])}
    procs = start_workers({f"pool{i}": ["--sim", spec]
                           for i in range(2)}, env=env)
    fleets = connect(procs, heartbeat_s=30.0)
    try:
        router = MultiPoolRouter(fleets)
        reqs = [Request(payload=i,
                        model=("cnn" if i % 2 == 0 else "lm"))
                for i in range(8)]
        for r in reqs:
            router.submit(r)
        for _ in range(3):
            router.step()
        for ex in router.executors.values():
            assert ex._handle.collect(ex) is not None
        assert router.obs.sources == ["pool0", "pool1"]
        instr = router.obs.snapshot(domain="slot")["counters"][
            "fleet_instructions_total"]["series"]
        assert any("pool=pool0" in k for k in instr)
        assert any("pool=pool1" in k for k in instr)
        shipped = {k: v for k, v in instr.items() if "pool=pool1" in k}
        assert shipped
        # coordinator-side channel accounting rode along in wall domain
        net = router.obs.snapshot(domain="wall")["counters"][
            "net_envelopes_total"]["series"]
        assert net["dir=out,kind=telemetry"] == 2
        assert net["dir=in,kind=telemetry_snap"] == 2

        for _ in range(2):                  # an unshipped window...
            router.step()
        procs["pool1"].kill()               # ...lost with the worker
        p1 = router.executors["pool1"]
        assert p1._handle.collect(p1) is None       # best-effort: no raise
        res = router.drain()
        assert list(router.dead) == ["pool1"]
        assert res.metrics.count("failed") == 0
        after = {k: v for k, v in router.obs.snapshot(domain="slot")[
            "counters"]["fleet_instructions_total"]["series"].items()
            if "pool=pool1" in k}
        assert after == shipped             # last shipped window survives
    finally:
        stop_workers(fleets, procs)


# --------------------------------------------------------------------------
# Metrics.summary: slots_observed
# --------------------------------------------------------------------------
def test_metrics_summary_reports_slots_observed():
    fleet = _stub_fleet(cores=("c", "p"), names=["a", "b"],
                        policy=WeightedFair(), service_steps=1)
    for i in range(4):
        fleet.submit(Request(i, model="ab"[i % 2]))
    res = fleet.drain()
    assert res.metrics.slots_observed == fleet._slot > 0
    assert res.metrics.summary()["slots_observed"] == fleet._slot

    router = _mk_router()
    for i in range(4):
        router.submit(Request(i, model="ab"[i % 2]))
    rres = router.drain()
    assert rres.metrics.slots_observed == router._steps > 0


# --------------------------------------------------------------------------
# serve CLI: --metrics validation
# --------------------------------------------------------------------------
def test_serve_fleet_rejects_bad_metrics_flags():
    from repro.launch import serve

    with pytest.raises(SystemExit) as ei:
        serve.main(["fleet", "--metrics-every", "4"])
    assert ei.value.code == 2
    with pytest.raises(SystemExit) as ei:
        serve.main(["fleet", "--metrics", "-", "--metrics-every", "0"])
    assert ei.value.code == 2
