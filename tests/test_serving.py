"""Serving-path integration: prefill -> greedy generate loop, int8 KV
path, and launcher CLI smoke."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_smoke
from repro.lm.model import init_cache, init_params
from repro.lm.steps import make_generate, make_serve_step

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", ["qwen2_0_5b", "zamba2_2_7b",
                                  "xlstm_350m"])
def test_generate_loop(arch):
    cfg = get_smoke(arch)
    p = init_params(cfg, KEY)
    B, P, G = 2, 8, 6
    prompt = jax.random.randint(KEY, (B, P), 0, cfg.vocab)
    cache = init_cache(cfg, B, P + G + 2)
    gen = make_generate(cfg, steps=G)
    toks, cache = gen(p, prompt, cache)
    assert toks.shape == (B, G)
    assert int(toks.min()) >= 0 and int(toks.max()) < cfg.vocab
    assert int(cache.pos) == P + G


def test_generate_deterministic():
    cfg = get_smoke("qwen2_0_5b")
    p = init_params(cfg, KEY)
    prompt = jax.random.randint(KEY, (1, 8), 0, cfg.vocab)
    gen = make_generate(cfg, steps=5)
    a, _ = gen(p, prompt, init_cache(cfg, 1, 16))
    b, _ = gen(p, prompt, init_cache(cfg, 1, 16))
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_int8_kv_generation_matches_bf16_mostly():
    """int8-KV greedy decode agrees with fp32-cache decode on most steps
    (static-scale quantization; EXPERIMENTS.md §Perf pair 3)."""
    cfg = get_smoke("qwen2_5_14b")
    p = init_params(cfg, KEY)
    prompt = jax.random.randint(KEY, (2, 8), 0, cfg.vocab)
    gen = make_generate(cfg, steps=8)
    ref, _ = gen(p, prompt, init_cache(cfg, 2, 24))
    q, _ = gen(p, prompt, init_cache(cfg, 2, 24, kv_dtype=jnp.int8))
    agree = float((np.asarray(ref) == np.asarray(q)).mean())
    assert agree >= 0.5, agree     # greedy paths can diverge after a flip


def test_serve_step_emits_valid_token():
    cfg = get_smoke("whisper_small")
    p = init_params(cfg, KEY)
    from repro.lm.model import encode
    enc = jax.random.normal(KEY, (2, cfg.enc_positions, cfg.d_model)) * 0.1
    memory = encode(p, cfg, enc)
    cache = init_cache(cfg, 2, 16, memory=memory, params=p)
    serve = make_serve_step(cfg)
    tok = jnp.zeros((2, 1), jnp.int32)
    logits, nxt, cache = serve(p, tok, cache)
    assert logits.shape == (2, 1, cfg.padded_vocab)
    assert int(nxt.max()) < cfg.vocab
    assert int(cache.pos) == 1


def test_serve_cli_lm_smoke(capsys):
    from repro.launch import serve
    rc = serve.main(["lm", "--arch", "qwen2_0_5b", "--smoke",
                     "--requests", "2", "--batch", "1",
                     "--prompt-len", "8", "--gen", "3"])
    assert rc == 0
    out = capsys.readouterr().out
    assert "prefill" in out and "decode" in out
    assert "p95" in out                  # engine latency metrics surfaced


def test_serve_cli_cnn_smoke(capsys):
    from repro.launch import serve
    # --requests 1 must be honored as a degenerate single-image run
    # (the old CLI silently bumped it to 2)
    rc = serve.main(["cnn", "mobilenet_v1", "--requests", "1",
                     "--batch", "1", "--image-size", "32", "--no-pallas"])
    assert rc == 0
    out = capsys.readouterr().out
    assert "streamed 1 request(s)" in out
    assert "img/s" in out and "p95" in out


def test_serve_cli_fleet_smoke(capsys):
    from repro.launch import serve
    # --requests below the member count leaves a model with no tagged
    # request; warm-up and serving must handle it (regression: the
    # warm-up used to crash on the untrafficked member)
    rc = serve.main(["fleet", "--models", "mbv1,sqz", "--mix", "0.7,0.3",
                     "--requests", "1", "--batch", "1",
                     "--image-size", "32", "--no-pallas",
                     "--policy", "weighted_fair", "--burst", "2"])
    assert rc == 0
    out = capsys.readouterr().out
    assert "fleet mobilenet_v1+squeezenet" in out
    assert "aggregate" in out and "p95" in out


def test_serve_cli_fleet_rejects_bad_mix(capsys):
    from repro.launch import serve
    # usage errors must exit 2 (argparse's convention) with a one-line
    # message on stderr — never a traceback
    for argv in (["fleet", "--models", "mbv1,sqz", "--mix", "0.5"],
                 ["fleet", "--models", "mbv1,nope"],
                 ["fleet", "--models", "mbv1,sqz", "--mix", "0.5,abc"],
                 ["fleet", "--models", "mbv1,sqz", "--mix", "0,1"],
                 ["fleet", "--models", "mbv1,sqz", "--mix", "-1,2"],
                 ["fleet", "--models", "mbv1,sqz", "--pools", "0"]):
        with pytest.raises(SystemExit) as ei:
            serve.main(argv)
        assert ei.value.code == 2, argv
        assert "error" in capsys.readouterr().err


def test_serve_cli_fleet_rejects_unknown_policy(capsys):
    from repro.launch import serve
    with pytest.raises(SystemExit) as ei:
        serve.main(["fleet", "--models", "mbv1,sqz", "--policy", "nope"])
    assert ei.value.code == 2
    err = capsys.readouterr().err
    assert "--policy" in err and "nope" in err


def test_serve_cli_fleet_multipool_with_trace(tmp_path, capsys):
    import json

    from repro.launch import serve
    trace = tmp_path / "trace.json"
    rc = serve.main(["fleet", "--models", "mbv1,sqz", "--requests", "4",
                     "--batch", "1", "--image-size", "32", "--no-pallas",
                     "--pools", "2", "--trace", str(trace)])
    assert rc == 0
    out = capsys.readouterr().out
    assert "over 2 pools" in out and "aggregate" in out
    assert "trace events" in out
    with open(trace) as f:
        doc = json.load(f)
    pools = {e["args"]["name"] for e in doc["traceEvents"]
             if e.get("name") == "process_name"}
    assert pools == {"pool0", "pool1"}
    assert any(e["ph"] == "X" for e in doc["traceEvents"])


def test_serve_cli_rejects_zero_requests():
    from repro.launch import serve
    with pytest.raises(SystemExit):
        serve.main(["cnn", "mobilenet_v1", "--requests", "0"])


def test_train_cli_smoke(tmp_path, capsys):
    from repro.launch import train
    rc = train.main(["--arch", "xlstm_350m", "--smoke", "--steps", "4",
                     "--global-batch", "2", "--seq-len", "16",
                     "--ckpt-dir", str(tmp_path)])
    assert rc == 0
    assert "loss" in capsys.readouterr().out
