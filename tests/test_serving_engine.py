"""The shared streaming engine API (ISSUE-4): CNN engine bitwise-equal to
the sequential forward, slot-refill traces under bursty arrivals, queue
backpressure bounds, admission policies, and the LM engine's submit/step
lifecycle (mid-flight joins, shim parity)."""
import jax
import numpy as np
import pytest

from repro.core.arch import BoardModel, DUAL_BASELINE
from repro.core.scheduler import build_schedule
from repro.dualcore.runtime import DualCoreRunner
from repro.models.cnn import build_model
from repro.serving import (DualCoreEngine, DualMeshEngine, Engine,
                           FixedRateAdmission, GreedyAdmission, QueueFull,
                           Request, percentile, poisson_arrivals, replay,
                           stream_images)

B = BoardModel()


def _runner(model, **kw):
    params, fwd, g = build_model(model)
    sched = build_schedule(g, DUAL_BASELINE, B, "balanced")
    return DualCoreRunner(model, params, sched, **kw), params, fwd


def _images(n, size=48, batch=1):
    return [jax.random.normal(k, (batch, size, size, 3))
            for k in jax.random.split(jax.random.PRNGKey(0), n)]


# --------------------------------------------------------------------------
# API basics
# --------------------------------------------------------------------------
def test_percentile_interpolates():
    xs = [1.0, 2.0, 3.0, 4.0]
    assert percentile(xs, 0) == 1.0
    assert percentile(xs, 100) == 4.0
    assert percentile(xs, 50) == pytest.approx(2.5)
    assert np.isnan(percentile([], 50))


def test_poisson_arrivals_fixed_and_monotone():
    a = poisson_arrivals(16, rate=1.0, seed=0)
    assert a == poisson_arrivals(16, rate=1.0, seed=0)   # deterministic
    assert a[0] == 0
    assert all(x <= y for x, y in zip(a, a[1:]))
    assert a != poisson_arrivals(16, rate=1.0, seed=1)


def test_zero_capacity_queue_rejected():
    """max_queue=0 could never admit work — replay() would spin forever
    retrying QueueFull; both engines must reject it at construction."""
    runner, _, _ = _runner("mobilenet_v1", use_pallas=False, fuse=False)
    with pytest.raises(ValueError, match="max_queue"):
        DualCoreEngine(runner, max_queue=0)


def test_poisson_arrivals_rejects_nonpositive_rate():
    with pytest.raises(ValueError, match="rate"):
        poisson_arrivals(4, rate=0.0)
    with pytest.raises(ValueError, match="rate"):
        poisson_arrivals(4, rate=-1.0)


def test_admission_policies_clamp():
    g = GreedyAdmission()
    assert g.admit(queued=5, in_flight=2, capacity=4) == 2
    assert g.admit(queued=1, in_flight=4, capacity=4) == 0
    f = FixedRateAdmission(per_step=1)
    assert f.admit(queued=5, in_flight=0, capacity=4) == 1
    assert f.admit(queued=0, in_flight=0, capacity=4) == 0


def test_engines_satisfy_protocol():
    runner, _, _ = _runner("mobilenet_v1", use_pallas=False, fuse=False)
    assert isinstance(DualCoreEngine(runner), Engine)


# --------------------------------------------------------------------------
# CNN engine: correctness
# --------------------------------------------------------------------------
@pytest.mark.parametrize("model", [
    "mobilenet_v1",
    pytest.param("mobilenet_v2", marks=pytest.mark.slow),
    pytest.param("squeezenet", marks=pytest.mark.slow),
])
def test_cnn_engine_bitwise_equals_run_sequential(model):
    """The streaming engine partitions the same step program the strictly
    serialized baseline runs, so outputs must be bitwise-identical (eager
    group execution, CPU interpret Pallas kernels)."""
    runner, _, _ = _runner(model, use_pallas=True, fuse=True,
                           jit_groups=False)
    imgs = _images(2)
    res = stream_images(runner, imgs)
    refs = runner.run_sequential(imgs)
    for out, ref in zip(res.outputs, refs):
        np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
    assert res.metrics.completed == 2
    assert all(m.finished_at is not None for m in res.metrics.requests)


def test_cnn_engine_slot_refill_trace_bursty_arrivals():
    """Admission refills the group-0 slot online: request r admitted at
    slot s runs group k at slot s+k exactly — including through the bubble
    an empty queue leaves behind."""
    runner, _, _ = _runner("mobilenet_v1", use_pallas=False, fuse=False)
    n_g = len(runner.groups)
    imgs = _images(3, size=32)
    rec = []
    eng = DualCoreEngine(runner, record=rec)
    eng.submit(imgs[0])
    eng.step()
    eng.step()                        # queue empty: bubble at slot 1
    eng.submit(imgs[1])
    eng.submit(imgs[2])
    eng.drain()
    admit = {0: 0, 1: 2, 2: 3}        # rid -> admission slot
    expect = sorted(((s, r, s - admit[r]) for r in admit
                     for s in range(admit[r], admit[r] + n_g)),
                    key=lambda t: (t[0], admit[t[1]]))
    assert [(s, r, g) for s, r, g, _ in rec] == expect
    # the bubble breaks the one-slot offset, so (unlike the saturated
    # case) adjacent streams may share a core within a slot — the device
    # queue serializes them; only the slot arithmetic is invariant


def test_cnn_engine_saturated_trace_matches_run_pipelined():
    """With every request available at slot 0 the engine reproduces the
    static ``run_pipelined`` dispatch schedule exactly (the shim test in
    test_dualcore covers the shim; this drives the engine directly)."""
    runner, _, _ = _runner("mobilenet_v1", use_pallas=False, fuse=False)
    n_g = len(runner.groups)
    rec = []
    stream_images(runner, _images(3, size=32), record=rec)
    assert [(s, i, g) for s, i, g, _ in rec] == \
        [(slot, i, slot - i) for slot in range(n_g + 2)
         for i in range(3) if 0 <= slot - i < n_g]


def test_cnn_engine_backpressure_and_flight_bound():
    runner, _, _ = _runner("mobilenet_v1", use_pallas=False, fuse=False)
    imgs = _images(4, size=32)
    eng = DualCoreEngine(runner, max_queue=2)
    eng.submit(imgs[0])
    eng.submit(imgs[1])
    with pytest.raises(QueueFull):
        eng.submit(imgs[2])
    eng.step()                        # admits one -> queue frees a slot
    eng.submit(imgs[2])               # now accepted
    while eng.has_work:
        assert eng.in_flight <= eng.capacity
        eng.step()
    res = eng.result()
    assert res.metrics.completed == 3
    assert [o.shape for o in res.outputs] == [(1, 1000)] * 3


def test_cnn_engine_replay_retries_on_backpressure():
    """replay() pushes submissions past QueueFull to later steps; every
    request still completes, in submission order, bitwise-equal to the
    plain forward."""
    runner, params, fwd = _runner("mobilenet_v1", use_pallas=False,
                                  fuse=False)
    imgs = _images(5, size=32)
    eng = DualCoreEngine(runner, max_queue=1)
    res = replay(eng, [Request(x) for x in imgs], [0, 0, 0, 1, 2])
    assert res.metrics.completed == 5
    for x, out in zip(imgs, res.outputs):
        np.testing.assert_array_equal(np.asarray(out),
                                      np.asarray(fwd(params, x)))
    # waiting in the queue shows up as wait time, not lost requests
    assert all(m.wait_s >= 0 for m in res.metrics.requests)


def test_cnn_engine_single_group_chain():
    """squeezenet under layer_type collapses to one exec group: capacity 1,
    admit-and-retire within a slot."""
    params, fwd, g = build_model("squeezenet")
    sched = build_schedule(g, DUAL_BASELINE, B, "layer_type")
    runner = DualCoreRunner("squeezenet", params, sched, use_pallas=False,
                            fuse=False)
    eng = DualCoreEngine(runner)
    assert eng.capacity == 1
    (x,) = _images(1, size=32)
    eng.submit(x)
    done = eng.step()
    assert len(done) == 1
    np.testing.assert_array_equal(np.asarray(done[0].output),
                                  np.asarray(fwd(params, x)))


# --------------------------------------------------------------------------
# LM engine
# --------------------------------------------------------------------------
@pytest.fixture(scope="module")
def lm_runner():
    from repro.configs.registry import get_smoke
    from repro.dualmesh import DualMeshRunner, split_mesh
    from repro.lm.model import init_params

    cfg = get_smoke("qwen2_0_5b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    return DualMeshRunner(cfg, params, split_mesh(jax.devices(), 0.5),
                          max_len=32), cfg


def test_lm_engine_lifecycle_and_shapes(lm_runner):
    runner, cfg = lm_runner
    p = jax.random.randint(jax.random.PRNGKey(1), (1, 4), 0, cfg.vocab)
    eng = DualMeshEngine(runner, group_size=2)
    t = eng.submit(Request(p, gen_steps=3))
    assert t.rid == 0 and eng.queued == 1 and not eng.in_flight
    eng.submit(Request(p, gen_steps=3))
    eng.step()                         # one admission per slot (stagger)
    assert eng.queued == 1 and eng.in_flight == 1
    eng.submit(Request(p, gen_steps=2))    # mid-flight join
    res = eng.drain()
    assert [o.shape for o in res.outputs] == [(1, 7), (1, 7), (1, 6)]
    assert res.stats["decode_tokens"] == 3 * 1 + 2 * 1 + 3 * 1
    assert all(m.latency_s >= m.service_s >= 0
               for m in res.metrics.requests)


def test_lm_engine_in_flight_cap_below_group_size_terminates(lm_runner):
    """max_in_flight < group_size must not livelock: with admission
    stalled at the cap, the fusion gate fuses the streams it has instead
    of waiting for group_size that can never accumulate."""
    runner, cfg = lm_runner
    p = jax.random.randint(jax.random.PRNGKey(1), (1, 4), 0, cfg.vocab)
    eng = DualMeshEngine(runner, group_size=2, max_in_flight=1)
    eng.submit(Request(p, gen_steps=2))
    eng.submit(Request(p, gen_steps=2))
    for _ in range(50):                 # bounded: a livelock would exhaust
        if not eng.has_work:
            break
        eng.step()
    res = eng.result()
    assert not eng.has_work
    assert [o.shape for o in res.outputs] == [(1, 6), (1, 6)]
    assert res.stats["fused_sizes"] == [1, 1]   # capacity-stalled fusion


def test_lm_engine_backpressure(lm_runner):
    runner, cfg = lm_runner
    p = jax.random.randint(jax.random.PRNGKey(1), (1, 4), 0, cfg.vocab)
    eng = DualMeshEngine(runner, group_size=1, max_queue=1)
    eng.submit(Request(p, gen_steps=1))
    with pytest.raises(QueueFull):
        eng.submit(Request(p, gen_steps=1))
    res = eng.drain()
    assert res.metrics.completed == 1


def test_lm_serve_shim_matches_engine(lm_runner):
    """DualMeshRunner.serve is now a submit-everything shim — identical
    outputs and token accounting to driving the engine directly."""
    runner, cfg = lm_runner
    prompts = [jax.random.randint(k, (1, 6), 0, cfg.vocab)
               for k in jax.random.split(jax.random.PRNGKey(2), 3)]
    shim = runner.serve(prompts, gen_steps=4, group_size=2)
    eng = DualMeshEngine(runner, group_size=2)
    for p in prompts:
        eng.submit(Request(p, gen_steps=4))
    res = eng.drain()
    for a, b in zip(shim.outputs, res.outputs):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for key in ("prefill_tokens", "decode_tokens", "total_tokens",
                "fused_sizes", "n_streams"):
        assert shim.stats[key] == res.stats[key], key
