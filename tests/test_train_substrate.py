"""Training substrate: optimizer, data pipeline, checkpoint/restore,
failure recovery, straggler accounting, elastic re-shard."""
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_smoke
from repro.data.pipeline import DataConfig, Prefetcher, SyntheticLM
from repro.lm.steps import make_init_state
from repro.train import checkpoint as ckpt
from repro.train.optimizer import AdamW
from repro.train.runner import FaultInjector, RunnerConfig, TrainRunner


# --------------------------------------------------------------------------
# Optimizer
# --------------------------------------------------------------------------
def test_adamw_reduces_quadratic():
    opt = AdamW(lr=0.1, weight_decay=0.0, warmup_steps=1, total_steps=100)
    params = {"w": jnp.array([3.0, -2.0])}
    state = opt.init(params)
    for _ in range(60):
        grads = {"w": 2 * params["w"]}
        params, state, _ = opt.apply(grads, state, params)
    assert float(jnp.abs(params["w"]).max()) < 0.3


def test_adamw_grad_clipping():
    opt = AdamW(lr=0.0, clip_norm=1.0)
    params = {"w": jnp.zeros(3)}
    state = opt.init(params)
    _, _, gnorm = opt.apply({"w": jnp.full(3, 100.0)}, state, params)
    assert float(gnorm) > 100  # reported pre-clip norm


def test_schedule_warmup_cosine():
    opt = AdamW(lr=1.0, warmup_steps=10, total_steps=100, min_lr_ratio=0.1)
    assert float(opt.schedule(jnp.array(0))) < 0.2
    peak = float(opt.schedule(jnp.array(10)))
    end = float(opt.schedule(jnp.array(99)))
    assert peak > 0.9
    assert 0.09 < end < 0.2


# --------------------------------------------------------------------------
# Data pipeline
# --------------------------------------------------------------------------
def test_data_deterministic_and_host_sharded():
    cfg = DataConfig(vocab=97, seq_len=32, global_batch=8, seed=3)
    a = SyntheticLM(cfg).batch_at(5)
    b = SyntheticLM(cfg).batch_at(5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    h0 = SyntheticLM(cfg, host_id=0, num_hosts=2).batch_at(5)
    h1 = SyntheticLM(cfg, host_id=1, num_hosts=2).batch_at(5)
    assert h0["tokens"].shape == (4, 32)
    assert not np.array_equal(h0["tokens"], h1["tokens"])
    assert a["tokens"].min() >= 1 and a["tokens"].max() < 97


def test_labels_are_shifted_tokens():
    cfg = DataConfig(vocab=97, seq_len=16, global_batch=2)
    b = SyntheticLM(cfg).batch_at(0)
    np.testing.assert_array_equal(b["labels"][:, :-1], b["tokens"][:, 1:])


def test_prefetcher():
    cfg = DataConfig(vocab=97, seq_len=8, global_batch=2)
    pf = Prefetcher(SyntheticLM(cfg), start_step=3)
    s, batch = pf.next()
    assert s == 3
    s2, _ = pf.next()
    assert s2 == 4
    pf.close()


# --------------------------------------------------------------------------
# Checkpointing
# --------------------------------------------------------------------------
def test_checkpoint_roundtrip(tmp_path):
    cfg = get_smoke("qwen2_0_5b")
    opt = AdamW()
    state = make_init_state(cfg, opt)(jax.random.PRNGKey(0))
    ckpt.save(str(tmp_path), state, 7)
    assert ckpt.latest_step(str(tmp_path)) == 7
    ref = jax.eval_shape(lambda: make_init_state(cfg, opt)(
        jax.random.PRNGKey(0)))
    restored = ckpt.restore(str(tmp_path), ref)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_gc_keeps_last_k(tmp_path):
    cfg = get_smoke("xlstm_350m")
    opt = AdamW()
    state = make_init_state(cfg, opt)(jax.random.PRNGKey(0))
    for s in (1, 2, 3, 4):
        ckpt.save(str(tmp_path), state, s, keep=2)
    dirs = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert dirs == ["step_00000003", "step_00000004"]
    assert ckpt.latest_step(str(tmp_path)) == 4


# --------------------------------------------------------------------------
# Fault tolerance + recovery
# --------------------------------------------------------------------------
def test_runner_trains_and_checkpoints(tmp_path):
    cfg = get_smoke("qwen2_0_5b")
    r = TrainRunner(cfg, RunnerConfig(ckpt_dir=str(tmp_path),
                                      ckpt_every=5, max_steps=10))
    out = r.run()
    assert out["final_step"] == 10
    assert np.isfinite(out["final_loss"])
    assert ckpt.latest_step(str(tmp_path)) == 10
    # loss should drop on structured synthetic data
    losses = [m["loss"] for m in out["metrics"]]
    assert losses[-1] < losses[0]


def test_runner_recovers_from_injected_fault(tmp_path):
    cfg = get_smoke("qwen2_0_5b")
    inj = FaultInjector(fail_at=(7,))
    r = TrainRunner(cfg, RunnerConfig(ckpt_dir=str(tmp_path),
                                      ckpt_every=5, max_steps=10),
                    fault_injector=inj)
    out = r.run()
    assert out["final_step"] == 10
    assert out["recoveries"] == 1


def test_recovery_is_bit_identical(tmp_path):
    """A job that crashes and replays reaches the same state as one that
    never crashed (deterministic data + checkpointed optimizer state)."""
    cfg = get_smoke("xlstm_350m")
    r1 = TrainRunner(cfg, RunnerConfig(ckpt_dir=str(tmp_path / "a"),
                                       ckpt_every=4, max_steps=8))
    out1 = r1.run()
    inj = FaultInjector(fail_at=(6,))
    r2 = TrainRunner(cfg, RunnerConfig(ckpt_dir=str(tmp_path / "b"),
                                       ckpt_every=4, max_steps=8),
                     fault_injector=inj)
    out2 = r2.run()
    assert out2["recoveries"] == 1
    np.testing.assert_allclose(out1["final_loss"], out2["final_loss"],
                               rtol=1e-6)


def test_resume_continues(tmp_path):
    cfg = get_smoke("xlstm_350m")
    r = TrainRunner(cfg, RunnerConfig(ckpt_dir=str(tmp_path),
                                      ckpt_every=3, max_steps=6))
    r.run(steps=3)
    r2 = TrainRunner(cfg, RunnerConfig(ckpt_dir=str(tmp_path),
                                       ckpt_every=3, max_steps=6))
    out = r2.run()
    assert out["final_step"] == 6


def test_elastic_remesh_roundtrip():
    """Re-sharding state onto a different mesh preserves values."""
    cfg = get_smoke("qwen2_0_5b")
    opt = AdamW()
    state = make_init_state(cfg, opt)(jax.random.PRNGKey(0))
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    r = TrainRunner(cfg, RunnerConfig(ckpt_dir="/tmp/unused_remesh"))
    new_state = r.remesh(state, mesh, None)
    for a, b in zip(jax.tree.leaves(state.params),
                    jax.tree.leaves(new_state.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
