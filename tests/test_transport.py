"""Distributed fleet transport (DESIGN.md §14): wire envelope framing +
drift rejection, the payload codec, the LocalTransport/FileTransport
mailbox bindings, and real worker processes over SocketTransport —
forced migration retires every request exactly once, a SIGKILL'd worker
recovers through the §12 path, and the collected streams + placement log
replay bitwise on fresh in-process fleets."""
import io
import os
import subprocess
import sys

import numpy as np
import pytest

sys.path.insert(0, os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))))

from repro.fleet import MultiPoolRouter, stream_signature  # noqa: E402
from repro.fleet.net import (FileTransport, LocalTransport,  # noqa: E402
                             wire)
from repro.fleet.net.worker import (build_sim_fleet,  # noqa: E402
                                    parse_sim_spec)
from repro.serving import Request  # noqa: E402

SPEC = "cnn:c:2,lm:p:3:opaque"
_ENV = {**os.environ,
        "PYTHONPATH": os.pathsep.join(
            [os.path.join(os.path.dirname(os.path.dirname(
                os.path.abspath(__file__))), "src"),
             os.environ.get("PYTHONPATH", "")])}


def _mixed_requests(n):
    return [Request(payload=i, model=("cnn" if i % 2 == 0 else "lm"))
            for i in range(n)]


# --------------------------------------------------------------------------
# wire envelopes: framing round-trip + drift rejection
# --------------------------------------------------------------------------
def test_envelope_round_trip():
    env = {"kind": "migrate_req", "src": "pool0", "dst": "pool1",
           "count": 3}
    doc = wire.unpack_env(wire.pack_env(env)[4:])
    assert doc == {"v": wire.WIRE_VERSION, **env}


def test_envelope_file_round_trip():
    buf = io.BytesIO()
    wire.write_env(buf, {"kind": "ping"})
    wire.write_env(buf, {"kind": "migrate_ack", "n": 2})
    buf.seek(0)
    assert wire.read_env(buf)["kind"] == "ping"
    assert wire.read_env(buf)["n"] == 2
    with pytest.raises(wire.WireClosed):
        wire.read_env(buf)          # clean EOF at the frame boundary


def test_unknown_kind_rejected_both_ways():
    with pytest.raises(wire.WireError, match="unknown envelope kind"):
        wire.pack_env({"kind": "teleport"})
    body = wire.pack_env({"kind": "ping"})[4:].replace(b"ping", b"warp")
    with pytest.raises(wire.WireError, match="unknown envelope kind"):
        wire.unpack_env(body)


def test_unknown_field_is_drift():
    good = wire.pack_env({"kind": "migrate_ack", "n": 1})[4:]
    doc = good.replace(b'"n":1', b'"n":1,"hops":9')
    with pytest.raises(wire.WireError, match="unknown fields"):
        wire.unpack_env(doc)


def test_version_mismatch_rejected():
    body = wire.pack_env({"kind": "ping"})[4:]
    drifted = body.replace(b'"v":%d' % wire.WIRE_VERSION, b'"v":99')
    with pytest.raises(wire.WireError, match="wire version"):
        wire.unpack_env(drifted)


def test_truncated_frame_is_closed():
    framed = wire.pack_env({"kind": "pong", "state": {"queued": 0}})
    for cut in (2, len(framed) - 3):        # mid-prefix and mid-body
        with pytest.raises(wire.WireClosed, match="truncated"):
            wire.read_env(io.BytesIO(framed[:cut]))


def test_undecodable_body_rejected():
    with pytest.raises(wire.WireError, match="undecodable|not an object"):
        wire.unpack_env(b"\xff\xfe nope")
    with pytest.raises(wire.WireError, match="not an object"):
        wire.unpack_env(b"[1,2]")


# --------------------------------------------------------------------------
# payload codec
# --------------------------------------------------------------------------
def test_codec_ndarray_round_trip():
    a = np.arange(12, dtype=np.float32).reshape(3, 4) * 0.5
    out = wire.decode_value(wire.encode_value({"x": a, "k": [1, "s"]}))
    np.testing.assert_array_equal(out["x"], a)
    assert out["x"].dtype == a.dtype and out["k"] == [1, "s"]


def test_codec_bytes_and_scalars():
    vals = [None, True, 3, 2.5, "hi", b"\x00\x01raw"]
    assert wire.decode_value(wire.encode_value(vals)) == vals


def test_codec_reserved_key_and_opaque_rejected():
    with pytest.raises(wire.WireError, match="reserved key"):
        wire.encode_value({"__nd__": [1]})
    with pytest.raises(wire.WireError, match="not wire-serializable"):
        wire.encode_value(object())


def test_request_and_completion_round_trip():
    req = Request(payload=np.ones((2, 2), np.int32), model="cnn",
                  gen_steps=4, deadline=1.5, priority=2)
    back = wire.decode_request(wire.encode_request(req))
    np.testing.assert_array_equal(back.payload, req.payload)
    assert (back.model, back.gen_steps, back.deadline, back.priority) == \
        ("cnn", 4, 1.5, 2)
    assert back.rid is req.rid is None      # rids never cross the wire


# --------------------------------------------------------------------------
# mailbox bindings: LocalTransport and FileTransport
# --------------------------------------------------------------------------
class _FakeRouter:
    """Minimal accounting hooks: translate frid -> 1000 + frid."""

    def __init__(self):
        self.dropped, self.received = [], []

    def on_send(self, src, dst, pairs):
        return [(1000 + frid, req) for frid, req in pairs]

    def on_drop(self, src, dst, pairs, *, seq, live):
        self.dropped.append((seq, live, len(pairs)))
        return len(pairs)

    def on_recv(self, dst, rid, frid):
        self.received.append((dst, rid, frid))


@pytest.mark.parametrize("kind", ["local", "file"])
def test_mailbox_binding_surface(kind, tmp_path):
    t = (LocalTransport() if kind == "local"
         else FileTransport(str(tmp_path / "spool")))
    t.bind(_FakeRouter())
    reqs = _mixed_requests(3)
    t.send("a", "b", list(enumerate(reqs)))
    assert t.in_transit == 3 and t.pending("a", "b") == 3
    assert t.pending("b", "a") == 0
    got = t.take("a", "b", 2)               # partial consume
    assert [rid for rid, _ in got] == [1000, 1001]
    assert t.pending("a", "b") == 1
    assert [rid for rid, _ in t.take("a", "b", None)] == [1002]
    assert t.in_transit == 0


def test_file_transport_spools_wire_frames(tmp_path):
    spool = str(tmp_path / "spool")
    t = FileTransport(spool)
    t.bind(_FakeRouter())
    t.send("a", "b", list(enumerate(_mixed_requests(2))))
    (name,) = os.listdir(spool)
    assert name.endswith(".a.b.frame")
    with open(os.path.join(spool, name), "rb") as f:
        env = wire.read_env(f)              # the spool IS the wire format
    assert env["kind"] == "frame" and len(env["items"]) == 2
    t.take("a", "b", 1)                     # partial: head frame rewritten
    assert len(os.listdir(spool)) == 1
    t.take("a", "b", None)
    assert os.listdir(spool) == []


def test_file_transport_drop_and_drain(tmp_path):
    t = FileTransport(str(tmp_path))
    fr = _FakeRouter()
    t.bind(fr)
    t.drop_send("a", "b", [(0, _mixed_requests(1)[0])], seq=7, live=True)
    assert fr.dropped == [(7, True, 1)] and t.in_transit == 0
    t.send("a", "b", list(enumerate(_mixed_requests(2))))
    t.send("c", "b", [(5, _mixed_requests(1)[0])])
    assert sorted(t.drain_for("b")) == [1000, 1001, 1005]
    assert t.in_transit == 0


def _run_migrating_fleet(transport):
    fleets = {"pool0": build_sim_fleet(SPEC), "pool1": build_sim_fleet(SPEC)}
    router = MultiPoolRouter(fleets, transport=transport)
    reqs = _mixed_requests(10)
    for r in reqs:
        router.submit(r)
    for _ in range(2):
        router.step()
    moved = router.migrate("pool0", "pool1")
    res = router.drain()
    statuses = {rid: router._metrics[rid].status
                for rid in range(len(reqs))}
    sigs = {p: stream_signature(ex.records)
            for p, ex in router.executors.items()}
    return moved, res, statuses, sigs


def test_file_transport_matches_local_bitwise(tmp_path):
    m_loc, res_loc, st_loc, sig_loc = _run_migrating_fleet(None)
    m_fil, res_fil, st_fil, sig_fil = _run_migrating_fleet(
        FileTransport(str(tmp_path / "spool")))
    assert m_fil == m_loc > 0
    assert len(res_fil.completions) == len(res_loc.completions) == 10
    assert st_fil == st_loc and sig_fil == sig_loc
    assert os.listdir(str(tmp_path / "spool")) == []    # fully consumed


# --------------------------------------------------------------------------
# sim-spec parsing
# --------------------------------------------------------------------------
def test_parse_sim_spec():
    assert parse_sim_spec(SPEC) == [("cnn", "c", 2, False),
                                    ("lm", "p", 3, True)]
    for bad in ("", "a:q:1", "a:c:0", "a:c:1:weird", "a:c"):
        with pytest.raises(ValueError):
            parse_sim_spec(bad)


# --------------------------------------------------------------------------
# real worker processes over SocketTransport
# --------------------------------------------------------------------------
def _spawn(n=2, **kw):
    from repro.fleet.net.coordinator import connect, start_workers

    procs = start_workers({f"pool{i}": ["--sim", SPEC] for i in range(n)},
                          env=_ENV, **kw)
    return procs, connect(procs, heartbeat_s=30.0)


def _stop(fleets, procs):
    from repro.fleet.net.coordinator import stop_workers

    stop_workers(fleets, procs)


def _assert_bitwise_replay(router, reqs, statuses):
    streams = router.streams()
    fresh = MultiPoolRouter({p: build_sim_fleet(SPEC) for p in streams})
    fresh.replay(streams, list(router.placements), reqs,
                 list(router.events))
    for pool, recs in streams.items():
        assert stream_signature(recs) == stream_signature(
            fresh.executors[pool].records), pool
    assert statuses == {rid: fresh._metrics[rid].status
                        for rid in range(len(reqs))}


def test_socket_fleet_migration_exactly_once_and_replays():
    procs, fleets = _spawn()
    try:
        router = MultiPoolRouter(fleets)
        reqs = _mixed_requests(10)
        for r in reqs:
            router.submit(r)
        for _ in range(2):
            router.step()
        assert router.migrate("pool0", "pool1") > 0     # forced migration
        res = router.drain()
        assert len(res.completions) == len(reqs)        # every request...
        assert len({c.ticket.rid for c in res.completions}) == len(reqs)
        assert router.duplicates_dropped == 0           # ...exactly once
        assert res.metrics.count("failed") == 0
        statuses = {rid: router._metrics[rid].status
                    for rid in range(len(reqs))}
    finally:
        _stop(fleets, procs)
    _assert_bitwise_replay(router, reqs, statuses)


def test_socket_fleet_sigkill_recovers_and_replays():
    procs, fleets = _spawn()
    try:
        router = MultiPoolRouter(fleets)
        reqs = _mixed_requests(12)
        for r in reqs:
            router.submit(r)
        for _ in range(2):
            router.step()
        procs["pool1"].kill()                           # real SIGKILL
        res = router.drain()
        assert list(router.dead) == ["pool1"]
        assert [e[0] for e in router.events].count("fail") == 1
        assert len(res.completions) == len(reqs)
        assert router.duplicates_dropped == 0
        assert res.metrics.count("recovered") > 0
        assert res.metrics.count("failed") == 0
        statuses = {rid: router._metrics[rid].status
                    for rid in range(len(reqs))}
    finally:
        _stop(fleets, procs)
    _assert_bitwise_replay(router, reqs, statuses)


def test_worker_rejects_wrong_pool_handshake():
    from repro.fleet.net.coordinator import dial, start_workers

    procs = start_workers({"pool0": ["--sim", SPEC]}, env=_ENV)
    try:
        chan = wire.Channel(dial(procs["pool0"].address, timeout_s=10.0),
                            timeout_s=10.0)
        chan.send({"kind": "hello", "pool": "poolX"})
        reply = chan.recv()
        assert reply["kind"] == "error"
        assert "poolX" in reply["msg"]
        chan.close()
    finally:
        for wp in procs.values():
            wp.kill()


# --------------------------------------------------------------------------
# CLI usage errors (exit 2) and worker entrypoint validation
# --------------------------------------------------------------------------
def _serve(*extra):
    return subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "fleet",
         "--models", "mbv1", "--requests", "1", *extra],
        env=_ENV, capture_output=True, text=True, timeout=120)


@pytest.mark.parametrize("flags", [
    ("--workers", "2", "--transport", "local"),
    ("--workers", "2", "--transport", "file"),
    ("--transport", "socket"),
    ("--transport", "file"),                # needs --pools >= 2
    ("--workers", "2", "--transport", "socket", "--pools", "2"),
    ("--workers", "2", "--transport", "socket", "--adapt"),
    ("--workers", "2", "--transport", "socket", "--slo-ms", "5"),
    ("--spool", "/tmp/x"),                  # only with --transport file
    ("--kill-worker", "pool0@1"),           # needs --workers
    ("--verify-replay",),                   # needs --workers
    ("--workers", "2", "--transport", "socket",
     "--kill-worker", "nope"),              # wants POOL@STEP
])
def test_serve_fleet_bad_combos_exit_2(flags):
    r = _serve(*flags)
    assert r.returncode == 2, r.stderr
    assert "error:" in r.stderr


def _worker(*argv):
    return subprocess.run(
        [sys.executable, "-m", "repro.fleet.worker", *argv],
        env=_ENV, capture_output=True, text=True, timeout=120)


def test_worker_cli_usage_errors_exit_2():
    assert _worker("--pool", "p0", "--sim", "a:q:1").returncode == 2
    assert _worker("--pool", "p0", "--models", "mbv1",
                   "--shed").returncode == 2      # --shed is sim-only
    assert _worker("--pool", "p0", "--models", "warpnet9").returncode == 2
